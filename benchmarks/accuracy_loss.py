"""Paper §VI-B accuracy claim: < 1% loss from interlayer compression.

No pretrained VOC models ship here, so the experiment is run end-to-end on
a trainable proxy: a small CNN on the procedural 4-class shapes dataset.
Train WITHOUT compression, then evaluate the SAME weights with the full
DCT+quant+bitmap pipeline inserted after every fusion layer at each of the
paper's four quantization levels — exactly the paper's deployment scenario
(compression is an inference-time memory feature, not a training change).

Outputs accuracy clean vs compressed per level + the compression ratio the
codec achieved on the eval activations.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor
from repro.data.synthetic import shapes_dataset
from repro.models import cnn


def train_tiny(params, imgs, labels, steps=300, lr=3e-3, batch=64, seed=0):
    opt_m = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    opt_v = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    rng = np.random.default_rng(seed)

    def loss_fn(p, x, y):
        logits = cnn.tiny_cnn_apply(p, x, train=True)
        ll = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(ll, y[:, None], axis=1))

    @jax.jit
    def step(p, m, v, x, y, i):
        g = jax.grad(loss_fn)(p, x, y)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        mh = jax.tree.map(lambda a: a / (1 - 0.9 ** (i + 1)), m)
        vh = jax.tree.map(lambda a: a / (1 - 0.99 ** (i + 1)), v)
        p = jax.tree.map(lambda a, mm, vv: a - lr * mm / (jnp.sqrt(vv) + 1e-8), p, mh, vh)
        return p, m, v

    n = imgs.shape[0]
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        params, opt_m, opt_v = step(params, opt_m, opt_v, imgs[idx], labels[idx],
                                    jnp.int32(i))
    return params


def evaluate(params, imgs, labels, schedule=None):
    stats = cnn.FusionStats() if schedule else None
    logits = cnn.tiny_cnn_apply(params, imgs, schedule, stats)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == labels))
    ratio = float(stats.overall_ratio()) if stats else 1.0
    return acc, ratio


def main(quick: bool = False):
    n_train, n_test, steps = (512, 256, 120) if quick else (2048, 512, 400)
    imgs, labels = shapes_dataset(0, n_train + n_test, size=32)
    imgs, labels = jnp.asarray(imgs), jnp.asarray(labels)
    tr_x, te_x = imgs[:n_train], imgs[n_train:]
    tr_y, te_y = labels[:n_train], labels[n_train:]

    params = cnn.tiny_cnn_init(jax.random.PRNGKey(0))
    params = train_tiny(params, tr_x, tr_y, steps=steps)
    clean_acc, _ = evaluate(params, te_x, te_y)

    out = {"clean_acc": clean_acc, "levels": {}}
    print(f"clean accuracy: {clean_acc*100:.2f}%")
    for level in range(4):
        class FixedLevel(cnn.CompressionSchedule):
            def policy(self, idx):
                return compressor.CompressionPolicy(level=level)
        acc, ratio = evaluate(params, te_x, te_y, FixedLevel(n_layers=3))
        out["levels"][level] = {"acc": acc, "ratio": ratio,
                                "acc_drop": clean_acc - acc}
        print(f"level {level}: acc {acc*100:6.2f}% (drop {100*(clean_acc-acc):+5.2f}%) "
              f"compression ratio {ratio*100:5.1f}%")

    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "accuracy_loss.json"), "w") as f:
        json.dump(out, f, indent=1)
    # the paper's claim at the gentle levels
    assert out["levels"][3]["acc_drop"] < 0.02, "gentle level must be ~lossless"
    return out


if __name__ == "__main__":
    main()
