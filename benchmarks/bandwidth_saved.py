"""Paper Table II: external memory access saved by compression — plus the
TPU-side analogues this framework actually deploys.

Part A (paper-faithful): per-inference interlayer data reduction (MB/figure)
for the five CNNs from the codec accounting, and time saved at the paper's
DMA rate (the paper's Table II uses the DW-axi-dmac; we report at both that
rate and v5e HBM bandwidth).

Part B (TPU deployment): per-step bytes saved by the three integration
points on a representative LM —
  * ActCompress: saved-for-backward residual bytes,
  * KVCompress: KV cache capacity + decode-read bytes,
  * GradCompress: cross-pod wire bytes
all analytic from shapes (the dry-run's §Roofline covers the compiled view).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import grad_comp
from repro.data.synthetic import natural_images
from repro.models import cnn
from repro.train import step as train_step

PAPER_TABLE2_MB = {  # paper: data reduction MB per inference image
    "yolov3_backbone": 54.36, "resnet50": 33.10, "vgg16_bn": 26.44,
    "mobilenet_v1": 18.11, "mobilenet_v2": 20.19,
}
DMA_BYTES_PER_S = 54.36e6 / 14.12e-3 * 0  # unused; derived per-net below
V5E_HBM = 819e9


def part_a(img_size=128, batch=1, verbose=True) -> dict:
    imgs = jnp.asarray(natural_images(0, batch, img_size, img_size))
    out = {}
    for name in PAPER_TABLE2_MB:
        init, apply = cnn.MODELS[name]
        params = init(jax.random.PRNGKey(1))
        stats = cnn.FusionStats()
        apply(params, imgs, cnn.CompressionSchedule(n_layers=10), stats)
        orig = sum(float(l["orig_bits"]) for l in stats.layers) / 8 / batch
        compd = sum(float(l["comp_bits"]) for l in stats.layers) / 8 / batch
        saved = orig - compd
        # paper's Table II DMA rate: 54.36 MB in 14.12 ms => ~3.85 GB/s
        dma = 54.36e6 / 14.12e-3
        out[name] = {
            "orig_mb": orig / 1e6, "comp_mb": compd / 1e6,
            "saved_mb": saved / 1e6,
            "saved_ms_dma": saved / dma * 1e3,
            "saved_us_v5e_hbm": saved / V5E_HBM * 1e6,
            "paper_saved_mb": PAPER_TABLE2_MB[name],
        }
        if verbose:
            r = out[name]
            print(f"{name:18s} saved {r['saved_mb']:7.2f} MB/img "
                  f"({r['saved_ms_dma']:5.2f} ms at paper DMA; "
                  f"{r['saved_us_v5e_hbm']:6.1f} us at v5e HBM) "
                  f"[paper: {r['paper_saved_mb']:.2f} MB at 224px VOC]")
    return out


def part_b(arch="yi_6b", seq=4096, batch=16, keep=4, verbose=True) -> dict:
    cfg = get_config(arch)
    d, L = cfg.d_model, cfg.n_layers
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    toks = seq * batch
    # ActCompress: one residual (B,S,D) bf16 per layer saved for backward
    resid_raw = L * toks * d * 2
    resid_comp = L * toks * d * (keep * keep + 8) / 64  # int8 corner + header
    # KVCompress: cache bytes (k+v) bf16 vs int8 DCT store
    kv_raw = L * toks * hkv * hd * 2 * 2
    kv_comp = L * toks * hkv * hd * 2 * (keep * keep + 4) / 64
    # GradCompress: wire bytes of one all-reduce of all grads
    api_params = None
    params = jax.eval_shape(
        lambda: __import__("repro.models.api", fromlist=["build"]).build(arch).init(jax.random.PRNGKey(0))
    )
    gw = grad_comp.wire_bytes(params, grad_comp.GradCompressConfig(keep=5))
    out = {
        "arch": arch,
        "act_raw_gb": resid_raw / 1e9, "act_comp_gb": resid_comp / 1e9,
        "act_ratio": resid_comp / resid_raw,
        "kv_raw_gb": kv_raw / 1e9, "kv_comp_gb": kv_comp / 1e9,
        "kv_ratio": kv_comp / kv_raw,
        "grad_raw_gb": gw["raw_bytes"] / 1e9,
        "grad_comp_gb": gw["compressed_bytes"] / 1e9,
        "grad_ratio": gw["ratio"],
    }
    if verbose:
        print(f"{arch} @ seq {seq} x batch {batch}, keep={keep}:")
        print(f"  ActCompress residuals {out['act_raw_gb']:.1f} -> "
              f"{out['act_comp_gb']:.2f} GB ({1/out['act_ratio']:.1f}x)")
        print(f"  KVCompress cache      {out['kv_raw_gb']:.1f} -> "
              f"{out['kv_comp_gb']:.2f} GB ({1/out['kv_ratio']:.1f}x)")
        print(f"  GradCompress wire     {out['grad_raw_gb']:.1f} -> "
              f"{out['grad_comp_gb']:.2f} GB ({1/out['grad_ratio']:.1f}x)")
    return out


def main(quick: bool = False):
    res = {"paper_table2": part_a(img_size=64 if quick else 128),
           "tpu_integration": part_b()}
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "bandwidth_saved.json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    main()
