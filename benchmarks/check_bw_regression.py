"""Kernel bandwidth regression guard for CI.

    python benchmarks/check_bw_regression.py BASELINE.json CURRENT.json \
        [--threshold 0.10]

Compares `achieved_bw_gbs` per kv_kernel_analysis row between the committed
baseline artifact and a freshly regenerated one, prints a markdown
before/after table (piped into $GITHUB_STEP_SUMMARY by the workflow), and
exits non-zero when any row regresses by more than the threshold. Rows
present in only one file (new archs, renamed cells) are listed but never
fail the check — only a like-for-like drop does: rows in the fresh analysis
with no committed baseline are reported as "new (no baseline)" and start
being guarded once a baseline refresh commits them, and a current file
that is ALL new rows passes (the disjoint-artifacts failure fires only
when the current run also dropped every baseline row).
"""
from __future__ import annotations

import argparse
import json
import sys


def iter_bw_rows(doc: dict):
    for key, row in doc.items():
        if isinstance(row, dict) and "achieved_bw_gbs" in row:
            yield key, float(row["achieved_bw_gbs"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max fractional achieved-bandwidth drop per row")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = dict(iter_bw_rows(json.load(f)))
    with open(args.current) as f:
        cur = dict(iter_bw_rows(json.load(f)))

    shared = sorted(set(base) & set(cur))
    regressions = []
    print("### kernel bandwidth vs committed baseline")
    print("| row | baseline GB/s | current GB/s | delta |")
    print("|---|---|---|---|")
    for key in shared:
        b, c = base[key], cur[key]
        delta = (c - b) / b if b else 0.0
        mark = ""
        if delta < -args.threshold:
            regressions.append((key, b, c, delta))
            mark = " **REGRESSION**"
        print(f"| {key} | {b:.1f} | {c:.1f} | {delta:+.1%}{mark} |")
    fresh = sorted(set(cur) - set(base))
    for key in fresh:
        print(f"| {key} | — | {cur[key]:.1f} | new (no baseline) |")
    for key in sorted(set(base) - set(cur)):
        print(f"| {key} | {base[key]:.1f} | — | removed row |")

    if not shared:
        if fresh:
            # every current row is new: nothing to guard yet, not a failure
            # (commit a refreshed baseline to start guarding them)
            print(f"\n{len(fresh)} new row(s), no baseline to compare "
                  "against yet")
            return 0
        print("\nno comparable rows — baseline/current artifacts disjoint")
        return 1
    if regressions:
        print(f"\n{len(regressions)} row(s) regressed more than "
              f"{args.threshold:.0%}:")
        for key, b, c, delta in regressions:
            print(f"  {key}: {b:.1f} -> {c:.1f} GB/s ({delta:+.1%})")
        return 1
    print(f"\nall {len(shared)} shared rows within {args.threshold:.0%} "
          f"of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
