"""Paper Table IV/V codec comparison on IDENTICAL activations.

Codecs:
  * paper (this work): 8x8 DCT + 2-step quant + bitmap index (+8b values)
  * bitmap on raw activations (EIE-style [25])
  * run-length on raw activations (Eyeriss JSSC'17 [23])
  * CSR (STICKER JSSC'20 [28])
  * zero-order entropy bound (ideal Huffman, the paper's rejected option)

Run on (a) ReLU activations (sparse — the favourable case for the raw-domain
codecs) and (b) leaky-ReLU activations (dense — the paper's motivating case
where raw-domain sparse codecs fail and only the DCT path compresses).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import codec
from repro.codec import families as families_lib
from repro.core import encode
from repro.data.synthetic import natural_images
from repro.models import cnn


def activations(dense: bool, size=64, batch=2, seed=0):
    """First-fusion-layer activations of a random CNN on 1/f images."""
    imgs = jnp.asarray(natural_images(seed, batch, size, size))
    params = cnn.tiny_cnn_init(jax.random.PRNGKey(2), cin=3, width=16)
    pre = cnn.bn(params["b1"], cnn.conv(params["c1"], imgs))
    act = cnn.leaky_relu(pre) if dense else cnn.relu(pre)
    return np.asarray(jnp.transpose(act, (0, 3, 1, 2)))  # (N, C, H, W)


def family_rows(act: np.ndarray, keep: int) -> dict:
    """One row per registered codec family on the SAME activations: the
    measured storage ratio of its per-tile accounting (variable-length for
    bitplane, fixed for dct/asc) and its reconstruction error — the
    runtime-scheme table the codec-family registry makes enumerable."""
    x = jnp.asarray(act.reshape(act.shape[0], -1, act.shape[-1]))
    # pad trailing dims to the 8-tileable geometry the block codec expects
    s = x.shape[1] - x.shape[1] % 8
    hd = x.shape[2] - x.shape[2] % 8
    x = x[:, :s, :hd] if s and hd else jnp.zeros((1, 8, 8), x.dtype)
    dense_b = encode.dense_bits(np.asarray(x), 16)
    q, scale = codec.compress_blocks(x, keep)
    rows = {}
    for name in families_lib.available_families():
        fam = families_lib.get_family(name)
        planes = fam.pack(q, scale, keep)
        bits = float(jnp.sum(fam.measured_tile_bits(q)))
        rec = fam.decompress(planes, keep, dtype=x.dtype)
        err = float(jnp.linalg.norm(rec - x) / (jnp.linalg.norm(x) + 1e-9))
        rows[name] = {
            "measured_ratio": bits / dense_b,
            "analytic_tile_bytes": fam.analytic_tile_bytes(keep),
            "rel_err": err,
            "planes": sorted(p.name for p in fam.plane_specs(keep, 8)),
        }
    return rows


def run_case(act: np.ndarray, level: int = 1) -> dict:
    dense_b = encode.dense_bits(act, 16)
    policy = codec.CompressionPolicy(level=level)
    comp = codec.paper_compress(jnp.asarray(act), policy)
    paper_b = float(encode.paper_codec_bits(
        np.asarray(codec.paper_masked_values(comp)), 8))
    # reconstruction error of the lossy paper codec
    rec = codec.paper_decompress(comp)
    rel_err = float(jnp.linalg.norm(rec - act) / (jnp.linalg.norm(act) + 1e-9))
    # the TPU runtime scheme on the same activations (fixed k x k corner)
    runtime = codec.Codec(keep=policy.keep())
    rt_c = runtime.compress(jnp.asarray(act))
    rt_rec = runtime.decompress(rt_c)
    rt_err = float(jnp.linalg.norm(rt_rec - act) / (jnp.linalg.norm(act) + 1e-9))
    out = {
        "dense_16b": 1.0,
        "paper_dct": paper_b / dense_b,
        "runtime_truncated": runtime.storage_stats(rt_c, 16)["ratio"],
        "runtime_rel_err": rt_err,
        "backend": codec.resolve_backend_name(None),
        "bitmap_raw": encode.bitmap_codec_bits(act, 16) / dense_b,
        "rle_raw": encode.rle_codec_bits(act, 16) / dense_b,
        "csr_raw": encode.csr_codec_bits(act, 16) / dense_b,
        "entropy_bound_raw": encode.entropy_bound_bits(
            np.round(act * 128).astype(np.int32)) / dense_b,
        "paper_rel_err": rel_err,
        "zero_frac": float((act == 0).mean()),
    }
    return out


def main(quick: bool = False):
    size = 32 if quick else 64
    results = {}
    for case, dense in (("relu_sparse", False), ("leaky_dense", True)):
        act = activations(dense, size=size)
        res = run_case(act)
        res["families"] = family_rows(act, keep=4)
        results[case] = res
        print(f"-- {case} (zeros {res['zero_frac']*100:.0f}%, backend {res['backend']})")
        for k in ("paper_dct", "runtime_truncated", "bitmap_raw", "rle_raw",
                  "csr_raw", "entropy_bound_raw"):
            print(f"   {k:18s} {res[k]*100:6.1f}% of dense")
        for name, row in res["families"].items():
            print(f"   family:{name:11s} {row['measured_ratio']*100:6.1f}% "
                  f"of dense  rel_err={row['rel_err']:.3f} "
                  f"planes={'/'.join(row['planes'])}")
        print(f"   paper codec relative reconstruction err {res['paper_rel_err']:.3f}")
    # paper's argument: on DENSE activations the raw codecs exceed dense
    # storage (index overhead, no zeros) while the DCT path still compresses
    assert results["leaky_dense"]["paper_dct"] < 0.8
    assert results["leaky_dense"]["bitmap_raw"] > 0.95
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "codec_compare.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
