"""Paper Table III + Fig. 16: layer-by-layer interlayer feature-map
compression ratios for the paper's five CNNs, using the bit-faithful codec
(8x8 DCT -> min-max quant -> Q-table -> bitmap sparse encoding).

No PASCAL VOC ships in this container; inputs are 1/f^2 power-spectrum
images — the second-order statistic that drives DCT energy compaction, so
ratios are comparable in kind (early layers compress hard, deep layers
less) if not in digit. The paper's own numbers are printed alongside.

Outputs benchmarks/artifacts/compression_table.{json,csv}.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import natural_images
from repro.models import cnn

PAPER_TABLE3 = {  # paper Table III, first ten fusion layers + overall (%)
    "vgg16_bn": ([8.97, 34.75, 37.00, 72.89, 42.23, 38.26, 67.93, 31.81, 18.41, 27.72], 30.63),
    "resnet50": ([18.99, 29.36, 26.47, 17.39, 20.59, 22.02, 18.63, 20.93, 19.66, 26.14], 52.51),
    "yolov3_backbone": ([13.37, 24.69, 32.74, 35.16, 28.79, 36.19, 23.35, 31.10, 27.13, 34.83], 65.63),
    "mobilenet_v1": ([21.05, 20.68, 44.38, 79.85, 60.28, 55.67, 56.76, 74.82, 47.26, 58.30], 61.02),
    "mobilenet_v2": ([27.63, 31.26, 88.41, 48.20, 77.64, 56.18, 66.51, 68.87, 57.82, 61.52], 71.05),
}

NETS = ["vgg16_bn", "resnet50", "mobilenet_v1", "mobilenet_v2", "yolov3_backbone"]


def run(img_size: int = 128, batch: int = 2, n_compress: int = 10,
        seed: int = 0, verbose: bool = True) -> dict:
    imgs = jnp.asarray(natural_images(seed, batch, img_size, img_size))
    results = {}
    for name in NETS:
        init, apply = cnn.MODELS[name]
        params = init(jax.random.PRNGKey(1)) if name != "yolov3_backbone" \
            else init(jax.random.PRNGKey(1))
        sched = cnn.CompressionSchedule(n_layers=n_compress)
        stats = cnn.FusionStats()
        apply(params, imgs, sched, stats)
        ratios = [float(r) for r in stats.ratios()[:n_compress]]
        # overall over the compressed prefix (paper reports whole-net with
        # uncompressed deep layers folded in; we report both)
        prefix = stats.layers[:n_compress]
        ob = sum(float(l["orig_bits"]) for l in prefix)
        cb = sum(float(l["comp_bits"]) for l in prefix)
        all_ob = sum(float(l["orig_bits"]) for l in stats.layers)
        all_cb = sum(float(l["comp_bits"]) for l in stats.layers)
        sizes_mb = [float(l["orig_bits"]) / 8e6 for l in stats.layers]
        comp_mb = [float(l["comp_bits"]) / 8e6 for l in stats.layers]
        results[name] = {
            "ratios_first10": ratios,
            "overall_first10": cb / ob,
            "overall_net": all_cb / all_ob,
            "orig_mb": sizes_mb,
            "comp_mb": comp_mb,
            "paper_first10": PAPER_TABLE3[name][0],
            "paper_overall": PAPER_TABLE3[name][1] / 100.0,
        }
        if verbose:
            ours = " ".join(f"{r*100:5.1f}" for r in ratios)
            paper = " ".join(f"{r:5.1f}" for r in PAPER_TABLE3[name][0])
            print(f"{name:18s} ours  [{ours}] overall(first10) {cb/ob*100:5.1f}%")
            print(f"{'':18s} paper [{paper}] overall(net)     {PAPER_TABLE3[name][1]:5.1f}%")
    return results


def main(quick: bool = False):
    res = run(img_size=64 if quick else 128, batch=1 if quick else 2)
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "compression_table.json"), "w") as f:
        json.dump(res, f, indent=1)
    # Fig. 16 data as CSV
    with open(os.path.join(art, "fig16_sizes.csv"), "w") as f:
        f.write("net,layer,orig_mb,comp_mb\n")
        for net, r in res.items():
            for i, (o, c) in enumerate(zip(r["orig_mb"], r["comp_mb"])):
                f.write(f"{net},{i},{o:.4f},{c:.4f}\n")
    # sanity assertions (the paper's qualitative claims)
    for net, r in res.items():
        assert r["overall_first10"] < 0.9, (net, "compression must help")
    assert res["vgg16_bn"]["ratios_first10"][0] < 0.35, "first VGG layer compresses hard"
    return res


if __name__ == "__main__":
    main()
