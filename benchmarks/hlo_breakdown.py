"""Per-op HBM/bytes breakdown of one dry-run cell — the §Perf 'profiler'.

    PYTHONPATH=src python -m benchmarks.hlo_breakdown --arch yi_6b \
        --shape decode_32k [--multi-pod] [--variant baseline] [--top 20]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.roofline import hlo as H


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    api = model_api.build(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, fargs, in_sh, out_sh = build_cell(api, mesh, args.shape, args.variant)
    with jax.set_mesh(mesh):
        kw = {"in_shardings": in_sh}
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        compiled = jax.jit(fn, **kw).lower(*fargs).compile()
    txt = compiled.as_text()
    st = H.analyze(txt)
    print(f"total: flops {st.flops:.3e}  bytes {st.bytes:.3e}  wire {st.wire:.3e}")
    print(f"\ntop-{args.top} byte movers (bytes x loop multipliers):")
    for b, comp, line in H.breakdown(txt, args.top):
        print(f"  {b/1e9:10.2f} GB  [{comp}]")
        print(f"      {line[:160]}")


if __name__ == "__main__":
    main()
