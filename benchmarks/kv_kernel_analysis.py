"""Analytic memory-term comparison for decode: raw cache vs XLA-compressed
vs the fused Pallas decompress-attend kernel — every applicable (arch, shape).

The dry-run can only measure what XLA materializes; the fused kernel's
traffic is determined by its BlockSpecs (packed int8 tiles + scales stream
HBM->VMEM once; decompressed K/V never exist in HBM), so its memory term is
computed here from shapes and the same v5e constants, per (arch x decode
shape) on the single-pod mesh. VMEM residency per grid step is checked
against the 16 MB budget — a kernel that doesn't fit is reported, not
assumed.

    PYTHONPATH=src python -m benchmarks.kv_kernel_analysis
"""
from __future__ import annotations

import json
import os

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.roofline.analysis import HBM_BW, hbm_bandwidth_row

BLOCK = 8
VMEM_BUDGET = 16 * 2**20
CHIPS = 256  # single-pod 16x16


def decode_cell(cfg, shape_name: str, keep: int = 4, tile_s: int = 512):
    seq, batch, kind = SHAPES[shape_name]
    if kind != "decode":
        return None
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        return {"skip": why}
    if cfg.attn_type != "gqa" or cfg.family not in ("dense", "moe", "vlm", "hybrid"):
        return {"skip": f"KVCompress inapplicable ({cfg.attn_type}/{cfg.family})"}
    hd, hkv, L = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    if hd % BLOCK:
        return {"skip": f"head_dim {hd} not 8-tileable"}
    if cfg.family == "hybrid":
        L = cfg.n_layers // max(cfg.attn_every, 1)  # shared-attn caches only

    # per-device partitioning: batch over data(16), heads over model(16)
    # when divisible, else sequence over model
    b_loc = max(batch // 16, 1)
    if hkv % 16 == 0 and hkv >= 16:
        hkv_loc, s_loc = hkv // 16, seq
    else:
        hkv_loc, s_loc = hkv, seq // 16

    # raw decode: read k+v bf16 once per layer
    raw = L * b_loc * s_loc * hkv_loc * hd * 2 * 2

    # fused kernel: packed int8 + f32 scales once per layer + tail +
    # amortized flush (packed-store DUS every 8 steps over the seq shard)
    per_tile = keep * keep + 4
    packed = L * b_loc * (s_loc // BLOCK) * hkv_loc * (hd // BLOCK) * per_tile * 2
    tail = L * b_loc * BLOCK * hkv_loc * hd * 2 * 2 * 2        # rw of raw tail
    flush = packed / BLOCK                                      # amortized rewrite
    fused = packed + tail + flush

    # VMEM per grid step: packed k/v tiles + scales + decompressed tiles f32
    ts8 = tile_s // BLOCK
    vmem = 2 * (ts8 * hkv_loc * (hd // BLOCK) * per_tile) \
        + 2 * (tile_s * hkv_loc * hd * 4) \
        + 2 * (cfg.n_heads * hd * 4)
    return {
        "raw_ms": raw / HBM_BW * 1e3,
        "xla_compressed_note": "~2x raw (unfused decompress, measured on yi)",
        "fused_ms": fused / HBM_BW * 1e3,
        "speedup": raw / fused,
        "vmem_ok": vmem <= VMEM_BUDGET,
        "vmem_mb": vmem / 2**20,
        "raw_gb_dev": raw / 1e9,
        "fused_gb_dev": fused / 1e9,
    }


def attend_paged_cell(cfg, shape_name: str, keep: int = 4,
                      occupancy: float = 0.5):
    """Achieved vs peak HBM bandwidth per decode step for `attend_paged`.

    The paged kernel walks the block table and streams ONLY mapped pages
    (packed int8 tiles + f32 scales), the raw bf16 tails, and the table
    itself; unmapped pool capacity is never touched. `occupancy` is the
    fraction of a slot's block-table rows that are mapped (serving fills
    pages as requests live — 0.5 matches the benchmark's 50% page budget).
    A dense-layout kernel must stream every slot's full max_seq allocation,
    so `bw_saving_vs_dense` is the measured-bytes half of the paged-pool
    claim: the win is in bytes that never cross HBM, not a faster stream.
    """
    seq, batch, kind = SHAPES[shape_name]
    if kind != "decode":
        return None
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        return {"skip": why}
    if cfg.attn_type != "gqa" or cfg.family not in ("dense", "moe", "vlm", "hybrid"):
        return {"skip": f"KVCompress inapplicable ({cfg.attn_type}/{cfg.family})"}
    hd, hkv, L = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    if hd % BLOCK:
        return {"skip": f"head_dim {hd} not 8-tileable"}
    if cfg.family == "hybrid":
        L = cfg.n_layers // max(cfg.attn_every, 1)
    b_loc = max(batch // 16, 1)
    if hkv % 16 == 0 and hkv >= 16:
        hkv_loc, s_loc, nq_loc = hkv // 16, seq, cfg.n_heads // 16
    else:
        hkv_loc, s_loc, nq_loc = hkv, seq // 16, cfg.n_heads

    per_tile = keep * keep + 4           # int8 corner + f32 scale, per 8x8
    blocks_loc = s_loc // BLOCK
    mapped = max(int(blocks_loc * occupancy), 1)
    # one mapped page's stream, per layer per slot: packed K + V planes
    page_bytes = hkv_loc * (hd // BLOCK) * per_tile * 2
    packed = L * b_loc * mapped * page_bytes
    table = L * b_loc * blocks_loc * 4                 # s32 block-table walk
    tails = L * b_loc * BLOCK * hkv_loc * hd * 2 * 2   # raw bf16 k+v tails
    qo = L * b_loc * nq_loc * hd * 2 * 2               # q in + attn out
    bytes_step = packed + table + tails + qo
    # attention math over what was streamed: QK^T + AV on mapped tokens
    flops = 4.0 * L * b_loc * nq_loc * hd * (mapped + 1) * BLOCK
    dense_bytes = L * b_loc * blocks_loc * page_bytes + table + tails + qo
    row = {
        "occupancy": occupancy,
        "mapped_pages_per_slot": mapped,
        "page_stream_bytes": page_bytes,
        "bw_saving_vs_dense": dense_bytes / bytes_step,
    }
    row.update(hbm_bandwidth_row(bytes_step, flops))
    return row


def main(quick: bool = False):
    rows = {}
    print(f"{'arch':24s} {'shape':12s} {'raw ms':>8s} {'fused ms':>9s} "
          f"{'speedup':>8s} {'VMEM MB':>8s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ("decode_32k", "long_500k"):
            r = decode_cell(cfg, shape)
            if r is None:
                continue
            rows[f"{arch}/{shape}"] = r
            if "skip" in r:
                print(f"{arch:24s} {shape:12s} skip: {r['skip']}")
                continue
            print(f"{arch:24s} {shape:12s} {r['raw_ms']:8.2f} {r['fused_ms']:9.3f} "
                  f"{r['speedup']:7.1f}x {r['vmem_mb']:8.2f}{'' if r['vmem_ok'] else '  !VMEM'}")
            assert r["vmem_ok"], (arch, shape, r["vmem_mb"])
            assert r["speedup"] > 4.0
            p = attend_paged_cell(cfg, shape)
            if p and "skip" not in p:
                rows[f"{arch}/{shape}/attend_paged"] = p
                print(f"{'':24s} {'^paged':12s} "
                      f"{p['achieved_bw_gbs']:8.1f}/{p['peak_bw_gbs']:.0f} GB/s "
                      f"(util {p['hbm_utilization']:.2f}, "
                      f"{p['bw_saving_vs_dense']:.1f}x fewer bytes vs dense)")
                assert 0.0 < p["hbm_utilization"] <= 1.0, p
                assert p["bw_saving_vs_dense"] > 1.0, p
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "kv_kernel_analysis.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
