"""Analytic memory-term comparison for decode: raw cache vs XLA-compressed
vs the fused Pallas decompress-attend kernel — every applicable (arch, shape).

The dry-run can only measure what XLA materializes; the fused kernel's
traffic is determined by its BlockSpecs (packed int8 tiles + scales stream
HBM->VMEM once; decompressed K/V never exist in HBM), so its memory term is
computed here from shapes and the same v5e constants, per (arch x decode
shape) on the single-pod mesh. VMEM residency per grid step is checked
against the 16 MB budget — a kernel that doesn't fit is reported, not
assumed.

    PYTHONPATH=src python -m benchmarks.kv_kernel_analysis
"""
from __future__ import annotations

import json
import os

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.roofline.analysis import HBM_BW, hbm_bandwidth_row

BLOCK = 8
VMEM_BUDGET = 16 * 2**20
CHIPS = 256  # single-pod 16x16


def decode_cell(cfg, shape_name: str, keep: int = 4, tile_s: int = 512):
    seq, batch, kind = SHAPES[shape_name]
    if kind != "decode":
        return None
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        return {"skip": why}
    if cfg.attn_type != "gqa" or cfg.family not in ("dense", "moe", "vlm", "hybrid"):
        return {"skip": f"KVCompress inapplicable ({cfg.attn_type}/{cfg.family})"}
    hd, hkv, L = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    if hd % BLOCK:
        return {"skip": f"head_dim {hd} not 8-tileable"}
    if cfg.family == "hybrid":
        L = cfg.n_layers // max(cfg.attn_every, 1)  # shared-attn caches only

    # per-device partitioning: batch over data(16), heads over model(16)
    # when divisible, else sequence over model
    b_loc = max(batch // 16, 1)
    if hkv % 16 == 0 and hkv >= 16:
        hkv_loc, s_loc = hkv // 16, seq
    else:
        hkv_loc, s_loc = hkv, seq // 16

    # raw decode: read k+v bf16 once per layer
    raw = L * b_loc * s_loc * hkv_loc * hd * 2 * 2

    # fused kernel: packed int8 + f32 scales once per layer + tail +
    # amortized flush (packed-store DUS every 8 steps over the seq shard)
    per_tile = keep * keep + 4
    packed = L * b_loc * (s_loc // BLOCK) * hkv_loc * (hd // BLOCK) * per_tile * 2
    tail = L * b_loc * BLOCK * hkv_loc * hd * 2 * 2 * 2        # rw of raw tail
    flush = packed / BLOCK                                      # amortized rewrite
    fused = packed + tail + flush

    # VMEM per grid step: packed k/v tiles + scales + decompressed tiles f32
    ts8 = tile_s // BLOCK
    vmem = 2 * (ts8 * hkv_loc * (hd // BLOCK) * per_tile) \
        + 2 * (tile_s * hkv_loc * hd * 4) \
        + 2 * (cfg.n_heads * hd * 4)
    return {
        "raw_ms": raw / HBM_BW * 1e3,
        "xla_compressed_note": "~2x raw (unfused decompress, measured on yi)",
        "fused_ms": fused / HBM_BW * 1e3,
        "speedup": raw / fused,
        "vmem_ok": vmem <= VMEM_BUDGET,
        "vmem_mb": vmem / 2**20,
        "raw_gb_dev": raw / 1e9,
        "fused_gb_dev": fused / 1e9,
    }


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def attend_paged_cell(cfg, shape_name: str, keep: int = 4,
                      occupancy: float = 0.5, pages_per_tile: int = 8,
                      pool_tokens: int | None = None):
    """Step cost and achieved HBM bandwidth for the multi-page tiled
    `attend_paged` vs its single-page-per-grid-step predecessor.

    Both kernels DMA one table entry's page per gather lane, so bytes
    scale with the blocks their GRID covers — the old kernel's grid was
    sized to pool CAPACITY (every step a tiny one-page tile: 8/128 of the
    MXU contraction, and one un-hideable DMA issue per step), the new one
    to the decode-ladder BUCKET covering the occupied context, fetching G
    pages per step into one (G*8, hd) MXU-shaped tile.  `occupancy` is the
    live fraction of `pool_tokens` (default: the shape's seq) — at low
    occupancy in a large pool the old grid is pure latency
    (`step_cost_vs_singlepage_grid` is the acceptance ratio); at full
    occupancy the G-wide tile turns the same bytes into fewer, larger DMAs
    (`achieved_bw_gbs` > `achieved_bw_singlepage_gbs`).  A dense-layout
    kernel streams every slot's full capacity allocation regardless —
    `bw_saving_vs_dense` stays the measured-bytes half of the paged claim.
    """
    seq, batch, kind = SHAPES[shape_name]
    if kind != "decode":
        return None
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        return {"skip": why}
    if cfg.attn_type != "gqa" or cfg.family not in ("dense", "moe", "vlm", "hybrid"):
        return {"skip": f"KVCompress inapplicable ({cfg.attn_type}/{cfg.family})"}
    hd, hkv, L = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_layers
    if hd % BLOCK:
        return {"skip": f"head_dim {hd} not 8-tileable"}
    if cfg.family == "hybrid":
        L = cfg.n_layers // max(cfg.attn_every, 1)
    b_loc = max(batch // 16, 1)
    if hkv % 16 == 0 and hkv >= 16:
        hkv_loc, s_loc, nq_loc = hkv // 16, seq, cfg.n_heads // 16
    else:
        hkv_loc, s_loc, nq_loc = hkv, seq // 16, cfg.n_heads

    per_tile = keep * keep + 4           # int8 corner + f32 scale, per 8x8
    cap_blocks = (pool_tokens if pool_tokens else s_loc) // BLOCK
    mapped = max(int(cap_blocks * occupancy), 1)
    # one page's stream, per layer per slot: packed K + V planes
    page_bytes = hkv_loc * (hd // BLOCK) * per_tile * 2
    tails = L * b_loc * BLOCK * hkv_loc * hd * 2 * 2   # raw bf16 k+v tails
    qo = L * b_loc * nq_loc * hd * 2 * 2               # q in + attn out

    def model(grid_blocks: int, g: int) -> dict:
        """One decode step with a grid over `grid_blocks` table entries,
        gathering g pages per step."""
        while grid_blocks % g:            # kernel's fit_tile: divisor of grid
            g -= 1
        grid_steps = L * b_loc * hkv_loc * (grid_blocks // g)
        packed = L * b_loc * grid_blocks * page_bytes
        table = L * b_loc * grid_blocks * 4            # s32 block-table walk
        bytes_step = packed + table + tails + qo
        # QK^T + AV over the tiles pl.when actually runs: whole g-page tiles
        # up to the watermark, plus the fused raw tail
        tiles = -(-min(mapped, grid_blocks) // g)
        flops = 4.0 * L * b_loc * nq_loc * hd * (tiles * g + 1) * BLOCK
        row = hbm_bandwidth_row(
            bytes_step, flops, grid_steps=grid_steps,
            mxu_efficiency=min(1.0, g * BLOCK / 128))
        row["grid_blocks"] = grid_blocks
        row["g"] = g
        return row

    # old kernel: grid = pool capacity, one page per step; new kernel:
    # grid = the ladder bucket covering the occupied context, G per step
    old = model(cap_blocks, 1)
    bucket_blocks = min(_next_pow2(mapped), cap_blocks)
    new = model(bucket_blocks, pages_per_tile)

    # VMEM per grid step (double-buffered inputs + scratch + out), G tile
    rep = max(nq_loc // hkv_loc, 1)
    g = new["g"]
    vmem = 2 * (2 * g * (hd // BLOCK) * per_tile                # packed+scale
                + 2 * BLOCK * hd * 4                            # raw tails
                + rep * hd * 4 + keep * BLOCK * 4) \
        + 2 * g * BLOCK * hd * 4 * 2 \
        + rep * hd * 4 * 2 + rep * 2 * 4
    dense_bytes = L * b_loc * cap_blocks * page_bytes + tails + qo
    row = {
        "occupancy": occupancy,
        "pool_tokens": cap_blocks * BLOCK,
        "mapped_pages_per_slot": mapped,
        "bucket_tokens": bucket_blocks * BLOCK,
        "pages_per_tile": g,
        "page_stream_bytes": page_bytes,
        "bw_saving_vs_dense": dense_bytes / new["bytes_per_step"],
        "step_cost_vs_singlepage_grid": old["step_bound_s"] / new["step_bound_s"],
        "achieved_bw_singlepage_gbs": old["achieved_bw_gbs"],
        "vmem_ok": vmem <= VMEM_BUDGET,
        "vmem_mb": vmem / 2**20,
    }
    row.update({k: v for k, v in new.items() if k not in ("grid_blocks", "g")})
    return row


def main(quick: bool = False):
    rows = {}
    print(f"{'arch':24s} {'shape':12s} {'raw ms':>8s} {'fused ms':>9s} "
          f"{'speedup':>8s} {'VMEM MB':>8s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ("decode_32k", "long_500k"):
            r = decode_cell(cfg, shape)
            if r is None:
                continue
            rows[f"{arch}/{shape}"] = r
            if "skip" in r:
                print(f"{arch:24s} {shape:12s} skip: {r['skip']}")
                continue
            print(f"{arch:24s} {shape:12s} {r['raw_ms']:8.2f} {r['fused_ms']:9.3f} "
                  f"{r['speedup']:7.1f}x {r['vmem_mb']:8.2f}{'' if r['vmem_ok'] else '  !VMEM'}")
            assert r["vmem_ok"], (arch, shape, r["vmem_mb"])
            assert r["speedup"] > 4.0
            # three paged operating points: serving steady state (half the
            # pool mapped), full occupancy (peak-bandwidth claim), and a
            # short context in a big pool (ladder + latency claim)
            paged_cells = {
                "attend_paged": attend_paged_cell(cfg, shape),
                "attend_paged_full": attend_paged_cell(cfg, shape,
                                                       occupancy=1.0),
                "attend_paged_short": attend_paged_cell(
                    cfg, shape, occupancy=256 / 4096, pool_tokens=4096),
            }
            for name, p in paged_cells.items():
                if not p or "skip" in p:
                    continue
                rows[f"{arch}/{shape}/{name}"] = p
                print(f"{'':24s} ^{name[7:]:11s} "
                      f"{p['achieved_bw_gbs']:8.1f}/{p['peak_bw_gbs']:.0f} GB/s "
                      f"(util {p['hbm_utilization']:.2f}, "
                      f"bucket {p['bucket_tokens']} G={p['pages_per_tile']}, "
                      f"{p['step_cost_vs_singlepage_grid']:.1f}x vs 1-page, "
                      f"{p['bw_saving_vs_dense']:.1f}x fewer bytes vs dense)")
                assert 0.0 < p["hbm_utilization"] <= 1.0, p
                if p["occupancy"] < 1.0:   # the byte saving IS occupancy:
                    assert p["bw_saving_vs_dense"] > 1.0, p
                else:                      # full pool = dense bytes + table
                    assert p["bw_saving_vs_dense"] > 0.98, p
                assert p["vmem_ok"], (arch, shape, name, p["vmem_mb"])
            full = paged_cells["attend_paged_full"]
            if full and "skip" not in full:
                # acceptance: >= 2x cheaper step at 256 live tokens in a 4k
                # pool; strictly higher achieved bandwidth at full occupancy
                short = paged_cells["attend_paged_short"]
                assert short["step_cost_vs_singlepage_grid"] >= 2.0, short
                assert full["achieved_bw_gbs"] > \
                    full["achieved_bw_singlepage_gbs"], full
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "kv_kernel_analysis.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
