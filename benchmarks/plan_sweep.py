"""CompressionPlan sweep: uniform vs pyramid vs budget-solved plans.

For each plan the sweep reports
  * the analytic compressed-KV ratio vs a raw bf16 cache (the paper's
    Table II bandwidth/footprint argument, per plan), and
  * the decode perplexity delta: teacher-forced next-token perplexity of a
    briefly-trained reduced LM decoding step-by-step OUT OF the compressed
    KV pool under the plan, against the same decode over the raw cache.
    (ActCompress leaves the forward bit-identical, so the KV path is where
    a plan's lossiness is visible.)

Writes benchmarks/artifacts/plan_sweep.json.  `--smoke` shrinks everything
to the CI-sized configuration (a couple of minutes on CPU).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec.plan import CompressionPlan, raw_kv_bytes_per_token
from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig
from repro.serve import engine as E
from repro.train import step as train_step


def train_params(api, ts, steps: int):
    tc = train_step.TrainConfig(
        microbatches=1, remat="full", param_dtype=jnp.float32,
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=steps + 20))
    state = train_step.init_train_state(api, tc)
    step = jax.jit(train_step.make_train_step(
        api, jax.make_mesh((1,), ("data",)), tc), donate_argnums=(0,))
    m = {"loss": jnp.nan}  # steps=0 benchmarks the untrained model
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
        state, m = step(state, b)
    return state["params"], float(m["loss"])


def decode_ce(api, params, toks, max_seq: int, sc: E.ServeConfig,
              prefix: int = 8) -> float:
    """Teacher-forced CE of positions prefix..S-1, decoded one token at a
    time out of the cache `sc` configures (raw or compressed-per-plan)."""
    prefill_fn, decode_fn, _, _ = E.make_steps(api, sc)
    prefill_fn, decode_fn = jax.jit(prefill_fn), jax.jit(decode_fn)
    b, s = toks.shape
    logits, cache = prefill_fn(params, toks[:, :prefix])
    lse = jax.nn.logsumexp(logits[:, -1], axis=-1)
    ce = [lse - jnp.take_along_axis(logits[:, -1], toks[:, prefix:prefix + 1],
                                    axis=-1)[:, 0]]
    for t in range(prefix, s - 1):
        logits, cache = decode_fn(params, toks[:, t], cache, jnp.int32(t))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ce.append(lse - jnp.take_along_axis(logits, toks[:, t + 1:t + 2],
                                            axis=-1)[:, 0])
    return float(jnp.mean(jnp.stack(ce)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (reduced arch, few steps)")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    api = model_api.build_reduced(args.arch)
    cfg = api.cfg
    steps = 10 if args.smoke else args.train_steps
    seq = min(args.max_seq, 48 if args.smoke else args.max_seq)
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    params, train_loss = train_params(api, ts, steps)
    toks = jnp.asarray(
        np.stack([ts.batch(1000 + i)["tokens"][0, :seq]
                  for i in range(4)]).astype(np.int32))
    base_ce = decode_ce(api, params, toks, args.max_seq,
                        E.ServeConfig(max_seq=args.max_seq))

    raw_kv = CompressionPlan.uniform(8).kv_cache_bytes(cfg, args.max_seq)
    budget = 0.7 * raw_kv
    plans = {
        "uniform_k8": CompressionPlan.uniform(8),
        "uniform_k4": CompressionPlan.uniform(4),
        "pyramid_8_4": CompressionPlan.pyramid(cfg.n_layers, 8, 4),
        "budget_70pct": CompressionPlan.from_budget(cfg, args.max_seq, budget),
    }

    raw_bytes = raw_kv_bytes_per_token(cfg) * args.max_seq
    results = {"arch": cfg.name, "train_loss": train_loss,
               "base_decode_ce": base_ce,
               "base_ppl": float(np.exp(base_ce)), "plans": {}}
    for name, plan in plans.items():
        sc = E.ServeConfig(max_seq=args.max_seq, kv_compress=True, plan=plan,
                           codec_backend="reference")
        ce = decode_ce(api, params, toks, args.max_seq, sc)
        kv_bytes = plan.kv_cache_bytes(cfg, args.max_seq)
        results["plans"][name] = {
            "spec": plan.to_spec(),
            "keeps": list(plan.keeps(cfg.n_layers)),
            "kv_ratio": kv_bytes / raw_bytes,
            "decode_ce": ce,
            "ppl_delta": float(np.exp(ce) - np.exp(base_ce)),
        }
        print(f"{name:14s} spec={plan.to_spec():40s} "
              f"kv_ratio={kv_bytes / raw_bytes:.3f} "
              f"ppl_delta={results['plans'][name]['ppl_delta']:+.4f}")

    # the budget-solved plan must honor its budget, and the pyramid must be
    # strictly cheaper than the gentlest uniform plan
    assert plans["budget_70pct"].kv_cache_bytes(cfg, args.max_seq) <= budget
    assert results["plans"]["pyramid_8_4"]["kv_ratio"] < \
        results["plans"]["uniform_k8"]["kv_ratio"]

    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "plan_sweep.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
