"""CompressionPlan sweep: uniform vs pyramid vs budget-solved plans.

For each plan the sweep reports
  * the analytic compressed-KV ratio vs a raw bf16 cache (the paper's
    Table II bandwidth/footprint argument, per plan), and
  * the decode perplexity delta: teacher-forced next-token perplexity of a
    briefly-trained reduced LM decoding step-by-step OUT OF the compressed
    KV pool under the plan, against the same decode over the raw cache.
    (ActCompress leaves the forward bit-identical, so the KV path is where
    a plan's lossiness is visible.)

`--codecs` adds the codec-family dimension: one curve row per registered
family x keep — analytic ratio, MEASURED resident KV bytes of the decoded
cache, and ppl delta — written to the artifact's ``codec_curves`` and fed
straight back into ``CompressionPlan.from_budget(curves=...)``, whose
solved mixed plan is then evaluated against the best uniform row fitting
each budget.

Writes benchmarks/artifacts/plan_sweep.json.  `--smoke` shrinks everything
to the CI-sized configuration (a couple of minutes on CPU).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import families as families_lib
from repro.codec.plan import CompressionPlan, raw_kv_bytes_per_token
from repro.core import kv_cache as kvc
from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig
from repro.serve import engine as E
from repro.train import step as train_step


def train_params(api, ts, steps: int):
    tc = train_step.TrainConfig(
        microbatches=1, remat="full", param_dtype=jnp.float32,
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=steps + 20))
    state = train_step.init_train_state(api, tc)
    step = jax.jit(train_step.make_train_step(
        api, jax.make_mesh((1,), ("data",)), tc), donate_argnums=(0,))
    m = {"loss": jnp.nan}  # steps=0 benchmarks the untrained model
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
        state, m = step(state, b)
    return state["params"], float(m["loss"])


def decode_ce(api, params, toks, max_seq: int, sc: E.ServeConfig,
              prefix: int = 8, measure: bool = False):
    """Teacher-forced CE of positions prefix..S-1, decoded one token at a
    time out of the cache `sc` configures (raw or compressed-per-plan).

    With `measure=True` returns ``(ce, measured_kv_bytes)`` — the codec
    families' data-dependent resident bytes of the final cache (what a
    measured-size allocator would actually hold for this traffic)."""
    prefill_fn, decode_fn, _, _ = E.make_steps(api, sc)
    prefill_fn, decode_fn = jax.jit(prefill_fn), jax.jit(decode_fn)
    b, s = toks.shape
    logits, cache = prefill_fn(params, toks[:, :prefix])
    lse = jax.nn.logsumexp(logits[:, -1], axis=-1)
    ce = [lse - jnp.take_along_axis(logits[:, -1], toks[:, prefix:prefix + 1],
                                    axis=-1)[:, 0]]
    for t in range(prefix, s - 1):
        logits, cache = decode_fn(params, toks[:, t], cache, jnp.int32(t))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ce.append(lse - jnp.take_along_axis(logits, toks[:, t + 1:t + 2],
                                            axis=-1)[:, 0])
    out = float(jnp.mean(jnp.stack(ce)))
    if measure:
        measured = kvc.measured_cache_bytes(cache) \
            if hasattr(cache, "segments") else None
        return out, measured
    return out


def _measured_block_bytes_per_token(cfg, measured: float, b: int,
                                    s: int) -> float:
    """Strip the raw bf16 tail rings from an end-of-decode measured total
    and normalize to bytes/token summed over layers — the unit the budget
    solver's curves and fits() reason in (the last partial block of each
    sequence lives in the tails, so only flushed tokens are in planes)."""
    tail = b * cfg.n_layers * 2 * 8 * cfg.n_kv_heads * \
        cfg.resolved_head_dim * 2
    flushed = b * ((s - 1) // 8) * 8
    return (measured - tail) / max(flushed, 1)


def codec_curves(api, params, toks, base_ce, max_seq: int, names, keeps):
    """One measured curve row per (codec family, keep): analytic ratio,
    MEASURED resident KV bytes of the decoded cache, ppl delta — and the
    per-layer measured bytes/token the budget solver consumes."""
    cfg = api.cfg
    raw_bytes = raw_kv_bytes_per_token(cfg) * max_seq
    b, s = toks.shape
    rows = []
    for cname in names:
        for keep in keeps:
            plan = CompressionPlan.uniform(keep).with_codec(cname)
            sc = E.ServeConfig(max_seq=max_seq, kv_compress=True, plan=plan,
                               codec_backend="reference")
            ce, measured = decode_ce(api, params, toks, max_seq, sc,
                                     measure=True)
            per_tok = _measured_block_bytes_per_token(
                cfg, measured, b, s) / cfg.n_layers
            rows.append({
                "codec": cname, "keep": keep,
                "kv_ratio": plan.kv_cache_bytes(cfg, max_seq) / raw_bytes,
                "measured_kv_bytes": measured,
                "bytes_per_token": per_tok,
                "decode_ce": ce,
                "ppl_delta": float(np.exp(ce) - np.exp(base_ce)),
            })
            print(f"codec={cname:9s} keep={keep} "
                  f"kv_ratio={rows[-1]['kv_ratio']:.3f} "
                  f"measured={measured / 1e3:7.1f}kB "
                  f"ppl_delta={rows[-1]['ppl_delta']:+.4f}")
    return rows


def solve_budget_ladder(api, params, toks, base_ce, max_seq: int, curves):
    """Race the curve-solved mixed plan against the best uniform row at a
    ladder of measured-byte budgets.

    At each budget: `from_budget(curves=...)` picks per-layer (codec, keep)
    by measured bytes; the uniform candidates are the curve rows whose
    uniform plan fits the same budget by its own measured accounting.  A
    WIN is the solved mixed plan strictly beating every fitting uniform's
    perplexity while its OWN measured block bytes also stay within the
    budget — better quality at equal-or-smaller measured KV memory."""
    cfg = api.cfg
    # solver budgets are batch=1 over max_seq with a bf16 tail ring (the
    # kv_cache_bytes convention); measured totals normalize through
    # `_measured_block_bytes_per_token` to compare in those terms
    b, s = toks.shape
    tail_bf16 = cfg.n_layers * 2 * 8 * cfg.n_kv_heads * \
        cfg.resolved_head_dim * 2
    dct8 = CompressionPlan.uniform(8).kv_cache_bytes(cfg, max_seq)
    out = []
    for frac in (0.45, 0.6, 0.75, 0.9):
        budget = frac * dct8
        try:
            solved = CompressionPlan.from_budget(cfg, max_seq, budget,
                                                 curves=curves)
        except ValueError:
            continue
        sc = E.ServeConfig(max_seq=max_seq, kv_compress=True, plan=solved,
                           codec_backend="reference")
        ce, measured = decode_ce(api, params, toks, max_seq, sc, measure=True)
        ppl_delta = float(np.exp(ce) - np.exp(base_ce))
        solved_equiv = _measured_block_bytes_per_token(
            cfg, measured, b, s) * max_seq + tail_bf16
        fitting = [r for r in curves
                   if cfg.n_layers * r["bytes_per_token"] * max_seq
                   + tail_bf16 <= budget]
        entry = {"budget_bytes": budget, "budget_frac_of_dct8": frac,
                 "solved_spec": solved.to_spec(),
                 "solved_ppl_delta": ppl_delta,
                 "solved_measured_kv_bytes": measured,
                 "solved_measured_budget_equiv": solved_equiv}
        if fitting:
            best = min(fitting, key=lambda r: (r["ppl_delta"],
                                               r["bytes_per_token"]))
            entry["best_uniform"] = {k: best[k] for k in
                                     ("codec", "keep", "ppl_delta",
                                      "measured_kv_bytes")}
            # WIN: better perplexity than every uniform plan this measured
            # budget admits, with the mixed plan's own measured footprint
            # inside the same budget
            entry["wins"] = bool(ppl_delta < best["ppl_delta"] - 1e-9
                                 and solved_equiv <= budget)
        else:
            entry["best_uniform"] = None
            entry["wins"] = False
        out.append(entry)
        tag = "WIN " if entry["wins"] else "    "
        print(f"{tag}budget={frac:.2f}x dct8  solved={solved.to_spec():48s} "
              f"ppl_delta={ppl_delta:+.4f} measured={measured / 1e3:.1f}kB")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (reduced arch, few steps)")
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--codecs", default=None,
                    help="comma-separated codec families (or 'all') to "
                         "sweep as measured curves; solves mixed plans "
                         "from the curves at a budget ladder and races "
                         "them against the best uniform rows")
    args = ap.parse_args(argv)

    api = model_api.build_reduced(args.arch)
    cfg = api.cfg
    steps = 10 if args.smoke else args.train_steps
    seq = min(args.max_seq, 48 if args.smoke else args.max_seq)
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    params, train_loss = train_params(api, ts, steps)
    toks = jnp.asarray(
        np.stack([ts.batch(1000 + i)["tokens"][0, :seq]
                  for i in range(4)]).astype(np.int32))
    base_ce = decode_ce(api, params, toks, args.max_seq,
                        E.ServeConfig(max_seq=args.max_seq))

    raw_kv = CompressionPlan.uniform(8).kv_cache_bytes(cfg, args.max_seq)
    budget = 0.7 * raw_kv
    plans = {
        "uniform_k8": CompressionPlan.uniform(8),
        "uniform_k4": CompressionPlan.uniform(4),
        "pyramid_8_4": CompressionPlan.pyramid(cfg.n_layers, 8, 4),
        "budget_70pct": CompressionPlan.from_budget(cfg, args.max_seq, budget),
    }

    raw_bytes = raw_kv_bytes_per_token(cfg) * args.max_seq
    results = {"arch": cfg.name, "train_loss": train_loss,
               "base_decode_ce": base_ce,
               "base_ppl": float(np.exp(base_ce)), "plans": {}}
    for name, plan in plans.items():
        sc = E.ServeConfig(max_seq=args.max_seq, kv_compress=True, plan=plan,
                           codec_backend="reference")
        ce = decode_ce(api, params, toks, args.max_seq, sc)
        kv_bytes = plan.kv_cache_bytes(cfg, args.max_seq)
        results["plans"][name] = {
            "spec": plan.to_spec(),
            "keeps": list(plan.keeps(cfg.n_layers)),
            "kv_ratio": kv_bytes / raw_bytes,
            "decode_ce": ce,
            "ppl_delta": float(np.exp(ce) - np.exp(base_ce)),
        }
        print(f"{name:14s} spec={plan.to_spec():40s} "
              f"kv_ratio={kv_bytes / raw_bytes:.3f} "
              f"ppl_delta={results['plans'][name]['ppl_delta']:+.4f}")

    # the budget-solved plan must honor its budget, and the pyramid must be
    # strictly cheaper than the gentlest uniform plan
    assert plans["budget_70pct"].kv_cache_bytes(cfg, args.max_seq) <= budget
    assert results["plans"]["pyramid_8_4"]["kv_ratio"] < \
        results["plans"]["uniform_k8"]["kv_ratio"]

    if args.codecs:
        names = families_lib.available_families() if args.codecs == "all" \
            else [s for s in args.codecs.split(",") if s]
        keeps = (8, 6, 4) if args.smoke else (8, 6, 4, 3, 2)
        curves = codec_curves(api, params, toks, base_ce, args.max_seq,
                              names, keeps)
        results["codec_curves"] = curves
        results["budget_ladder"] = solve_budget_ladder(
            api, params, toks, base_ce, args.max_seq, curves)
        # acceptance: at least one budget where the curve-solved mixed plan
        # beats the best fitting uniform on perplexity at equal-or-smaller
        # measured KV bytes
        assert any(e["wins"] for e in results["budget_ladder"]), \
            results["budget_ladder"]

    art = os.path.join(os.path.dirname(__file__), "artifacts")
    os.makedirs(art, exist_ok=True)
    with open(os.path.join(art, "plan_sweep.json"), "w") as f:
        json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
