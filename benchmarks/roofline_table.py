"""Aggregate dry-run artifacts into the §Dry-run / §Roofline markdown tables.

    PYTHONPATH=src python -m benchmarks.roofline_table [--variant baseline]

Reads benchmarks/artifacts/dryrun/*.json, emits:
  * artifacts/roofline_<variant>.md — the full per-cell table
  * stdout — the table + hillclimb-candidate ranking
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def load(variant: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "dryrun", f"*__{variant}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x*1e3:8.2f}ms"


def table(recs: list[dict], mesh: str | None = None) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "bound/step | frac | useful | MFU-bound |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            if mesh is None or mesh in r["cell"]:
                arch, shape, m = r["cell"].split("/")[:3]
                lines.append(
                    f"| {arch} | {shape} | {m} | — | — | — | skipped | — | — | — | — |")
            continue
        if r["status"] != "ok" or (mesh and r["mesh"] != mesh):
            continue
        comp, memy, coll = r["compute_s"], r["memory_s"], r["collective_s"]
        bound = max(comp, memy, coll)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(comp)} | "
            f"{fmt_s(memy)} | {fmt_s(coll)} | {r['dominant']} | {fmt_s(bound)} | "
            f"{r['roofline_fraction']:.2f} | {r['useful_flop_ratio']:.2f} | "
            f"{r['mfu_bound']*100:.1f}% |"
        )
    return "\n".join(lines)


def candidates(recs: list[dict]) -> str:
    """Hillclimb candidate ranking: how far the dominant term sits above the
    compute term (the achievable speedup if the bottleneck were removed)."""
    rows = []
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        comp = max(r["compute_s"], 1e-9)
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((bound / comp, r))
    rows.sort(reverse=True, key=lambda t: t[0])
    out = ["\nhillclimb candidates (bound/compute — headroom if bottleneck removed):"]
    for gap, r in rows[:10]:
        out.append(
            f"  {gap:9.1f}x  {r['arch']:24s} {r['shape']:12s} dom={r['dominant']:10s} "
            f"coll_frac={r['collective_s']/max(r['memory_s']+r['collective_s']+r['compute_s'],1e-9):.2f}"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    recs = load(args.variant)
    if not recs:
        raise SystemExit(f"no artifacts for variant {args.variant}")
    md = (
        f"## Roofline — variant `{args.variant}`\n\n### single-pod 16x16\n\n"
        + table(recs, "16x16")
        + "\n\n### multi-pod 2x16x16\n\n"
        + table(recs, "2x16x16")
    )
    out = os.path.join(ART, f"roofline_{args.variant}.md")
    with open(out, "w") as f:
        f.write(md + "\n")
    print(md)
    print(candidates(recs))
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    print(f"\n{n_ok} ok, {n_skip} skipped -> {out}")


if __name__ == "__main__":
    main()
