"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

  compression_table  Table III + Fig. 16 (layer-by-layer ratios, 5 CNNs)
  codec_compare      Table IV/V (DCT codec vs bitmap/RLE/CSR/entropy)
  accuracy_loss      §VI-B (<1% accuracy loss, 4 quantization levels)
  bandwidth_saved    Table II (memory access saved; + TPU integration points)

The roofline/dry-run tables (§Dry-run, §Roofline) are produced by
`python -m repro.launch.dryrun`, not here — they need the 512-device flag.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller inputs / fewer steps (CI mode)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()

    from benchmarks import accuracy_loss, bandwidth_saved, codec_compare, \
        compression_table, kv_kernel_analysis

    suite = {
        "compression_table": compression_table.main,
        "codec_compare": codec_compare.main,
        "accuracy_loss": accuracy_loss.main,
        "bandwidth_saved": bandwidth_saved.main,
        "kv_kernel_analysis": kv_kernel_analysis.main,
    }
    if args.only:
        suite = {args.only: suite[args.only]}

    failed = []
    for name, fn in suite.items():
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"--- {name} ok in {time.time()-t0:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
            print(f"--- {name} FAILED")
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
