"""Serving throughput: static lock-step vs continuous batching vs the PAGED
pool over the compressed KV store (qwen2_0_5b-shaped configs, CPU interpret
mode).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke] \
        [--mesh 4x1]

Emits benchmarks/artifacts/serve_throughput.json with tokens/s,
slot-utilization, a warmup/prefill/decode/host wall-time split, and p50/p99
TTFT + inter-token latency per scheduler. The point being measured: with
per-slot positions each pool slot is occupied exactly as long as its
request lives (the paper's dynamic feature-map buffer allocation, serving
edition), so a mixed workload finishes in fewer decode steps at higher slot
utilization than the wave-at-a-time baseline.

The `continuous_sync` row is the pre-pipeline loop (batch-1 prefills,
per-token host sync, XLA compiles inside the measured window); the
`continuous` row runs the AOT-warmed ladder + packed admission + one-step-
deep async readback, and must beat it >= 1.3x on decode tokens/s with
bitwise-identical greedy outputs and post-warmup prefill share below
decode share.

The paged rows push the same idea into the STORE: at a page budget of 50%
of the dense pool's packed bytes, the paged engine runs 2x the concurrent
slots (asserted >= 1.5x live at once on a uniform probe workload) with
greedy outputs bitwise identical to the dense engine on the mixed workload
— paying only for blocks requests actually fill, not slots x max_seq.

`--mesh DATAxMODEL` runs the schedulers on a host device mesh (slots on
data, heads on model) and records the mesh axis sizes plus the per-device
slice of the KV pool in the artifact — needs that many local devices (CI
forces 4 with XLA_FLAGS=--xla_force_host_platform_device_count=4).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.serve import engine as E

ART = pathlib.Path(__file__).parent / "artifacts"


def build_workload(cfg, n_requests: int, prompt_hi: int, new_hi: int, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(2, prompt_hi // 4), prompt_hi + 1))
        max_new = int(rng.integers(max(2, new_hi // 4), new_hi + 1))
        reqs.append(E.Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new))
    return reqs


def run_one(api, params, sc, batch, scheduler, workload_args, reqs=None,
            label=None):
    eng = E.Engine(api, params, sc, batch=batch, scheduler=scheduler)
    reqs = build_workload(api.cfg, *workload_args) if reqs is None else reqs
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    wall = time.perf_counter() - t0
    st = eng.stats
    # first token per request comes from prefill logits, not the decode loop
    dec_tok = st["tokens_out"] - st["requests"]
    pool = eng.kv_pool_stats()
    lat = eng.latency_stats() if eng.scheduler == "continuous" else \
        {"ttft_p50_s": 0.0, "ttft_p99_s": 0.0, "itl_p50_s": 0.0,
         "itl_p99_s": 0.0}
    row = {
        "scheduler": label or eng.scheduler,
        "batch": batch,
        "requests": st["requests"],
        "tokens_out": st["tokens_out"],
        "decode_steps": st["steps"],
        "slot_utilization": round(eng.slot_utilization(), 4),
        "peak_live_slots": st["peak_live_slots"],
        "decode_s": round(st["decode_s"], 4),
        "prefill_s": round(st["prefill_s"], 4),
        "host_s": round(st["host_s"], 4),
        "warmup_s": round(st["warmup_s"], 4),
        "wall_s": round(wall, 4),
        "decode_tok_per_s": round(dec_tok / st["decode_s"], 2) if st["steps"] else 0.0,
        "tok_per_s": round(st["tokens_out"] / max(wall, 1e-9), 2),
        "ttft_p50_s": round(lat["ttft_p50_s"], 4),
        "ttft_p99_s": round(lat["ttft_p99_s"], 4),
        "itl_p50_s": round(lat["itl_p50_s"], 4),
        "itl_p99_s": round(lat["itl_p99_s"], 4),
        "mean_out_len": round(float(np.mean([len(r.out_tokens) for r in done])), 2),
        "kv_pool_bytes": pool["kv_pool_bytes"],
        "slots_per_gb": round(pool["slots_per_gb"], 1),
    }
    if eng.paged:
        row.update(pool_pages=pool["pool_pages"],
                   page_bytes=pool["page_bytes"],
                   peak_pages_in_use=pool["peak_pages_in_use"],
                   admit_blocked_on_pages=st["admit_blocked_on_pages"],
                   decode_buckets=list(eng.decode_ladder.buckets),
                   mean_decode_bucket=round(
                       st["decode_bucket_tokens"] / max(st["steps"], 1), 1))
    return eng, done, row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + workload (CI wiring check)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--kv-keep", type=int, default=8)
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serve mesh, e.g. 4x1 (default: none)")
    args = ap.parse_args(argv)

    cfg = get_config("qwen2_0_5b").reduced()
    api = model_api.build("qwen2_0_5b", cfg)
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    mesh = mesh_lib.make_serve_mesh(args.mesh)

    if args.smoke:
        n_req, prompt_hi, new_hi, max_seq = 5, 12, 6, 48
        probe_plen, probe_new = 8, 8
    else:
        n_req, prompt_hi, new_hi, max_seq = args.requests, 24, 16, 96
        probe_plen, probe_new = 16, 16

    kw = dict(max_seq=max_seq, kv_compress=True, kv_keep=args.kv_keep,
              codec_backend="reference", mesh=mesh)
    workload = (n_req, prompt_hi, new_hi)

    # static wave baseline; the PRE-pipeline continuous loop (one prompt per
    # prefill call, synchronous per-token readback, compiles under traffic);
    # and the pipelined engine (AOT-warmed ladder + packed admission +
    # one-step-deep async readback). continuous_sync is the row every
    # "steady-state" claim is measured against.
    engines_rows = [
        run_one(api, params, E.ServeConfig(**kw), args.batch, "static",
                workload),
        run_one(api, params,
                E.ServeConfig(**kw, packed_admission=False, async_host=False),
                args.batch, "continuous", workload, label="continuous_sync"),
        run_one(api, params, E.ServeConfig(**kw, aot_warmup=True),
                args.batch, "continuous", workload),
    ]

    # ---- paged pool: 50% page budget, 2x the slots --------------------
    # dense packed capacity is batch * max_seq/8 block groups; give the
    # paged pool HALF that in pages and TWICE the slots. Parity leg: the
    # mixed workload must come out token-for-token identical to the dense
    # engine. Probe leg: a uniform workload of 2*batch requests must be
    # live on >= 1.5x the dense engine's slots at once.
    pool_pages = (args.batch * max_seq // 8) // 2
    sc_paged = E.ServeConfig(max_seq=max_seq, kv_compress=True,
                             kv_keep=args.kv_keep, codec_backend="reference",
                             mesh=mesh, pool_pages=pool_pages,
                             aot_warmup=True)
    engines_rows.append(run_one(api, params, sc_paged, 2 * args.batch,
                                "continuous", workload, label="paged"))
    # same engine with the decode ladder pinned to the single full-capacity
    # bucket: the pre-ladder decode step. Tokens must be bitwise identical
    # (the ladder is an exact slice) — only the per-step cost moves.
    engines_rows.append(run_one(
        api, params,
        E.ServeConfig(max_seq=max_seq, kv_compress=True,
                      kv_keep=args.kv_keep, codec_backend="reference",
                      mesh=mesh, pool_pages=pool_pages, aot_warmup=True,
                      decode_buckets=False),
        2 * args.batch, "continuous", workload, label="paged_full_bucket"))
    probe = [E.Request(uid=i,
                       prompt=np.arange(probe_plen, dtype=np.int32) + i,
                       max_new=probe_new) for i in range(2 * args.batch)]
    engines_rows.append(run_one(api, params, sc_paged, 2 * args.batch,
                                "continuous", workload, reqs=probe,
                                label="paged_probe"))

    rows = [row for _, _, row in engines_rows]
    stat, cont_sync, cont, paged, paged_full, paged_probe = rows

    # mesh provenance + the per-device slice of the sharded KV pool (the
    # banked-buffer accounting: what one "bank" actually holds)
    pool = engines_rows[0][0].kv_pool_stats()
    mesh_axes = {a: int(mesh.shape[a]) for a in mesh.axis_names} \
        if mesh is not None else None
    summary = {
        "arch": cfg.name,
        "batch": args.batch,
        "kv_keep": args.kv_keep,
        "max_seq": max_seq,
        "smoke": bool(args.smoke),
        "mesh": mesh_axes,
        "kv_pool_bytes": pool["kv_pool_bytes"],
        "kv_bytes_per_device": round(pool["kv_bytes_per_device"], 1),
        "step_reduction": round(
            1.0 - cont["decode_steps"] / max(stat["decode_steps"], 1), 4),
        # pipeline gain: warmed+packed+async decode rate over the pre-PR
        # continuous loop (which pays its XLA compiles inside the measured
        # window and syncs the host every token)
        "pipeline_decode_speedup": round(
            cont["decode_tok_per_s"] / max(cont_sync["decode_tok_per_s"],
                                           1e-9), 2),
        "paged_pool_pages": pool_pages,
        "paged_slot_gain": round(paged_probe["peak_live_slots"] /
                                 max(cont["peak_live_slots"], 1), 2),
        # decode-ladder gain: warmed paged engine with the auto bucket
        # ladder vs the same engine pinned at the full-capacity bucket
        "decode_ladder_speedup": round(
            paged["decode_tok_per_s"] /
            max(paged_full["decode_tok_per_s"], 1e-9), 2),
        "mean_decode_bucket": paged["mean_decode_bucket"],
        "rows": rows,
    }
    ART.mkdir(exist_ok=True)
    out = ART / "serve_throughput.json"
    out.write_text(json.dumps(summary, indent=2) + "\n")

    print(f"arch={cfg.name} batch={args.batch} requests={n_req} "
          f"kv_keep={args.kv_keep} mesh={mesh_lib.mesh_desc(mesh)} "
          f"(compressed pool, {pool['kv_bytes_per_device']/1e3:.1f} kB KV "
          f"per device)")
    for r in rows:
        print(f"  {r['scheduler']:<15} batch={r['batch']} "
              f"steps={r['decode_steps']:<4} "
              f"slot_util={r['slot_utilization']:.2f} "
              f"peak_live={r['peak_live_slots']} "
              f"decode_tok/s={r['decode_tok_per_s']:.1f} "
              f"prefill={r['prefill_s']:.1f}s decode={r['decode_s']:.1f}s "
              f"host={r['host_s']:.1f}s warmup={r['warmup_s']:.1f}s "
              f"ttft_p50={r['ttft_p50_s']*1e3:.0f}ms "
              f"itl_p50={r['itl_p50_s']*1e3:.0f}ms wall={r['wall_s']:.1f}s")
    print(f"decode-step reduction continuous vs static: "
          f"{summary['step_reduction'] * 100:.0f}%")
    print(f"pipeline decode speedup (warmed+packed+async vs sync loop): "
          f"{summary['pipeline_decode_speedup']:.2f}x")
    print(f"paged: {pool_pages} pages (50% budget) on {2 * args.batch} slots "
          f"-> peak {paged_probe['peak_live_slots']} live "
          f"({summary['paged_slot_gain']:.2f}x dense), "
          f"{paged['slots_per_gb']:.0f} vs {cont['slots_per_gb']:.0f} slots/GB "
          f"-> {out}")
    print(f"decode ladder {paged['decode_buckets']}: mean bucket "
          f"{paged['mean_decode_bucket']:.1f}/{max_seq} tokens, "
          f"{summary['decode_ladder_speedup']:.2f}x vs full-capacity bucket")
    # sanity for CI: both schedulers must have served every token requested
    assert stat["requests"] == cont["requests"] == n_req
    assert cont["tokens_out"] == stat["tokens_out"] == cont_sync["tokens_out"]
    # pipeline acceptance: the warmed packed/async engine is a pure
    # scheduling change — greedy outputs bitwise identical to the pre-PR
    # synchronous loop on the same workload
    sync_done = engines_rows[1][1]
    dense_done = engines_rows[2][1]
    for a, b in zip(sync_done, dense_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    # with warmup excluded from the measured window, admission is cheap:
    # prefill wall share must sit below decode share on the warmed row,
    # and the decode rate must beat the sync loop (which pays compiles +
    # a per-token host sync inside decode_s) by >= 1.3x
    assert cont["prefill_s"] < cont["decode_s"], \
        (cont["prefill_s"], cont["decode_s"])
    assert summary["pipeline_decode_speedup"] >= 1.3, \
        summary["pipeline_decode_speedup"]
    # paged acceptance: bitwise greedy parity with the dense pool on the
    # mixed workload, and >= 1.5x concurrent slots at the 50% page budget
    paged_done = engines_rows[3][1]
    for a, b in zip(dense_done, paged_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert paged_probe["peak_live_slots"] >= 1.5 * cont["peak_live_slots"], \
        (paged_probe["peak_live_slots"], cont["peak_live_slots"])
    # decode-ladder acceptance: the bucketed engine is an exact slice of
    # the full-capacity step (bitwise tokens), actually dispatched below
    # capacity on this workload, and costs no throughput (host-side bucket
    # pick + smaller attends; interpret-mode CPU wall time is noisy, so
    # gate at >= 0.9x rather than demanding a CPU speedup)
    full_done = engines_rows[4][1]
    for a, b in zip(paged_done, full_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert paged["mean_decode_bucket"] < max_seq, paged["mean_decode_bucket"]
    assert summary["decode_ladder_speedup"] >= 0.9, \
        summary["decode_ladder_speedup"]
    return summary


if __name__ == "__main__":
    main()
