"""Serving throughput: static lock-step vs continuous batching vs the PAGED
pool over the compressed KV store (qwen2_0_5b-shaped configs, CPU interpret
mode).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke] \
        [--mesh 4x1]

Emits benchmarks/artifacts/serve_throughput.json with tokens/s,
slot-utilization, a warmup/prefill/decode/host wall-time split, and p50/p99
TTFT + inter-token latency per scheduler. The point being measured: with
per-slot positions each pool slot is occupied exactly as long as its
request lives (the paper's dynamic feature-map buffer allocation, serving
edition), so a mixed workload finishes in fewer decode steps at higher slot
utilization than the wave-at-a-time baseline.

The `continuous_sync` row is the pre-pipeline loop (batch-1 prefills,
per-token host sync, XLA compiles inside the measured window); the
`continuous` row runs the AOT-warmed ladder + packed admission + one-step-
deep async readback, and must beat it >= 1.3x on decode tokens/s with
bitwise-identical greedy outputs and post-warmup prefill share below
decode share.

The paged rows push the same idea into the STORE: at a page budget of 50%
of the dense pool's packed bytes, the paged engine runs 2x the concurrent
slots (asserted >= 1.5x live at once on a uniform probe workload) with
greedy outputs bitwise identical to the dense engine on the mixed workload
— paying only for blocks requests actually fill, not slots x max_seq.

The `paged_tiered` row shrinks the device pool to barely one request's
horizon and backs it with a host-RAM tier: the probe workload can only run
via forced eviction (cold slots parked, their compressed pages spilled) and
fault-path restores, and its tokens must stay bitwise the untiered probe's.
The `prefix_shared` row serves a common-system-prompt workload at a page
budget of exactly 1x prefix + Nx suffix: copy-on-write prefix sharing
stores the prefix once and runs all N slots live where the unshared engine
fits only a third of them. Both rows assert zero new jit traces after
warmup — the tier fault path and the share verification ride the same
AOT-warmed ladders as everything else.

`--mesh DATAxMODEL` runs the schedulers on a host device mesh (slots on
data, heads on model) and records the mesh axis sizes plus the per-device
slice of the KV pool in the artifact — needs that many local devices (CI
forces 4 with XLA_FLAGS=--xla_force_host_platform_device_count=4).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.serve import engine as E

ART = pathlib.Path(__file__).parent / "artifacts"


def build_workload(cfg, n_requests: int, prompt_hi: int, new_hi: int, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(2, prompt_hi // 4), prompt_hi + 1))
        max_new = int(rng.integers(max(2, new_hi // 4), new_hi + 1))
        reqs.append(E.Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new))
    return reqs


def run_one(api, params, sc, batch, scheduler, workload_args, reqs=None,
            label=None):
    eng = E.Engine(api, params, sc, batch=batch, scheduler=scheduler)
    snap = eng.trace_counts.snapshot()  # warmup (if any) already ran
    reqs = build_workload(api.cfg, *workload_args) if reqs is None else reqs
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    wall = time.perf_counter() - t0
    new_traces = eng.trace_counts.delta(snap)
    st = eng.stats
    # first token per request comes from prefill logits, not the decode loop
    dec_tok = st["tokens_out"] - st["requests"]
    pool = eng.kv_pool_stats()
    lat = eng.latency_stats() if eng.scheduler == "continuous" else \
        {"ttft_p50_s": 0.0, "ttft_p99_s": 0.0, "itl_p50_s": 0.0,
         "itl_p99_s": 0.0}
    row = {
        "scheduler": label or eng.scheduler,
        "batch": batch,
        "requests": st["requests"],
        "tokens_out": st["tokens_out"],
        "decode_steps": st["steps"],
        "slot_utilization": round(eng.slot_utilization(), 4),
        "peak_live_slots": st["peak_live_slots"],
        "decode_s": round(st["decode_s"], 4),
        "prefill_s": round(st["prefill_s"], 4),
        "host_s": round(st["host_s"], 4),
        "warmup_s": round(st["warmup_s"], 4),
        "wall_s": round(wall, 4),
        "decode_tok_per_s": round(dec_tok / st["decode_s"], 2) if st["steps"] else 0.0,
        "tok_per_s": round(st["tokens_out"] / max(wall, 1e-9), 2),
        "ttft_p50_s": round(lat["ttft_p50_s"], 4),
        "ttft_p99_s": round(lat["ttft_p99_s"], 4),
        "itl_p50_s": round(lat["itl_p50_s"], 4),
        "itl_p99_s": round(lat["itl_p99_s"], 4),
        "mean_out_len": round(float(np.mean([len(r.out_tokens) for r in done])), 2),
        "kv_pool_bytes": pool["kv_pool_bytes"],
        "slots_per_gb": round(pool["slots_per_gb"], 1),
        "new_traces": new_traces,
    }
    if eng.paged:
        row.update(pool_pages=pool["pool_pages"],
                   page_bytes=pool["page_bytes"],
                   peak_pages_in_use=pool["peak_pages_in_use"],
                   admit_blocked_on_pages=st["admit_blocked_on_pages"],
                   decode_buckets=list(eng.decode_ladder.buckets),
                   mean_decode_bucket=round(
                       st["decode_bucket_tokens"] / max(st["steps"], 1), 1))
    if sc.tiered:
        row.update(host_pool_pages=pool["host_pool_pages"],
                   pages_spilled=pool["pages_spilled"],
                   pages_restored=pool["pages_restored"],
                   slots_parked=pool["slots_parked"],
                   slots_resumed=pool["slots_resumed"])
    if sc.prefix_sharing:
        row.update(prefix_shared_blocks=pool["prefix_shared_blocks"],
                   shared_physical_pages=pool["shared_physical_pages"],
                   prefix_demotions=pool["prefix_demotions"])
    return eng, done, row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + workload (CI wiring check)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--kv-keep", type=int, default=8)
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serve mesh, e.g. 4x1 (default: none)")
    args = ap.parse_args(argv)

    cfg = get_config("qwen2_0_5b").reduced()
    api = model_api.build("qwen2_0_5b", cfg)
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    mesh = mesh_lib.make_serve_mesh(args.mesh)

    if args.smoke:
        n_req, prompt_hi, new_hi, max_seq = 5, 12, 6, 48
        probe_plen, probe_new = 8, 8
    else:
        n_req, prompt_hi, new_hi, max_seq = args.requests, 24, 16, 96
        probe_plen, probe_new = 16, 16

    kw = dict(max_seq=max_seq, kv_compress=True, kv_keep=args.kv_keep,
              codec_backend="reference", mesh=mesh)
    workload = (n_req, prompt_hi, new_hi)

    # static wave baseline; the PRE-pipeline continuous loop (one prompt per
    # prefill call, synchronous per-token readback, compiles under traffic);
    # and the pipelined engine (AOT-warmed ladder + packed admission +
    # one-step-deep async readback). continuous_sync is the row every
    # "steady-state" claim is measured against.
    engines_rows = [
        run_one(api, params, E.ServeConfig(**kw), args.batch, "static",
                workload),
        run_one(api, params,
                E.ServeConfig(**kw, packed_admission=False, async_host=False),
                args.batch, "continuous", workload, label="continuous_sync"),
        run_one(api, params, E.ServeConfig(**kw, aot_warmup=True),
                args.batch, "continuous", workload),
    ]

    # ---- paged pool: 50% page budget, 2x the slots --------------------
    # dense packed capacity is batch * max_seq/8 block groups; give the
    # paged pool HALF that in pages and TWICE the slots. Parity leg: the
    # mixed workload must come out token-for-token identical to the dense
    # engine. Probe leg: a uniform workload of 2*batch requests must be
    # live on >= 1.5x the dense engine's slots at once.
    pool_pages = (args.batch * max_seq // 8) // 2
    sc_paged = E.ServeConfig(max_seq=max_seq, kv_compress=True,
                             kv_keep=args.kv_keep, codec_backend="reference",
                             mesh=mesh, pool_pages=pool_pages,
                             aot_warmup=True)
    engines_rows.append(run_one(api, params, sc_paged, 2 * args.batch,
                                "continuous", workload, label="paged"))
    # same engine with the decode ladder pinned to the single full-capacity
    # bucket: the pre-ladder decode step. Tokens must be bitwise identical
    # (the ladder is an exact slice) — only the per-step cost moves.
    engines_rows.append(run_one(
        api, params,
        E.ServeConfig(max_seq=max_seq, kv_compress=True,
                      kv_keep=args.kv_keep, codec_backend="reference",
                      mesh=mesh, pool_pages=pool_pages, aot_warmup=True,
                      decode_buckets=False),
        2 * args.batch, "continuous", workload, label="paged_full_bucket"))
    def mk_probe():
        return [E.Request(uid=i,
                          prompt=np.arange(probe_plen, dtype=np.int32) + i,
                          max_new=probe_new) for i in range(2 * args.batch)]
    engines_rows.append(run_one(api, params, sc_paged, 2 * args.batch,
                                "continuous", workload, reqs=mk_probe(),
                                label="paged_probe"))

    # ---- tiered pool: device pool too small for ONE slot's lifetime ----
    # barely-above-horizon device pages + a host tier the size of the paged
    # row's pool: the probe workload cannot run without forced eviction
    # (park/spill) and fault-path restores, and its tokens must still be
    # bitwise the untiered probe's.
    horizon = (probe_plen + probe_new - 1) // 8
    sc_tier = E.ServeConfig(max_seq=max_seq, kv_compress=True,
                            kv_keep=args.kv_keep, codec_backend="reference",
                            mesh=mesh, pool_pages=horizon + 1,
                            host_pool_pages=pool_pages, aot_warmup=True)
    engines_rows.append(run_one(api, params, sc_tier, 2 * args.batch,
                                "continuous", workload, reqs=mk_probe(),
                                label="paged_tiered"))

    # ---- prefix sharing: common system prompt, unique suffixes --------
    # N requests share one 2-block (16-token) prefix + a 4-token unique
    # tail. Shared pool budget = 1x prefix + N x 1-page suffix horizon —
    # EXACTLY enough for all N live at once when the prefix is stored
    # once; the unshared engine at the same budget can only hold
    # floor(budget/3) slots live.
    def mk_shared(n):
        rng = np.random.default_rng(7)
        pre = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        return [E.Request(uid=i, prompt=np.concatenate(
            [pre, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)]),
            max_new=12) for i in range(n)]
    n_share = 2 * args.batch
    share_pages = 2 + n_share  # (20+12-1)//8 = 3 pages/req, 2 shared
    kw_share = dict(max_seq=max_seq, kv_compress=True, kv_keep=args.kv_keep,
                    codec_backend="reference", mesh=mesh,
                    pool_pages=share_pages, aot_warmup=True)
    engines_rows.append(run_one(
        api, params, E.ServeConfig(**kw_share), n_share, "continuous",
        workload, reqs=mk_shared(n_share), label="prefix_unshared"))
    engines_rows.append(run_one(
        api, params, E.ServeConfig(**kw_share, prefix_sharing=True),
        n_share, "continuous", workload, reqs=mk_shared(n_share),
        label="prefix_shared"))

    rows = [row for _, _, row in engines_rows]
    (stat, cont_sync, cont, paged, paged_full, paged_probe, tiered,
     pre_unsh, pre_sh) = rows

    # mesh provenance + the per-device slice of the sharded KV pool (the
    # banked-buffer accounting: what one "bank" actually holds)
    pool = engines_rows[0][0].kv_pool_stats()
    mesh_axes = {a: int(mesh.shape[a]) for a in mesh.axis_names} \
        if mesh is not None else None
    summary = {
        "arch": cfg.name,
        "batch": args.batch,
        "kv_keep": args.kv_keep,
        "max_seq": max_seq,
        "smoke": bool(args.smoke),
        "mesh": mesh_axes,
        "kv_pool_bytes": pool["kv_pool_bytes"],
        "kv_bytes_per_device": round(pool["kv_bytes_per_device"], 1),
        "step_reduction": round(
            1.0 - cont["decode_steps"] / max(stat["decode_steps"], 1), 4),
        # pipeline gain: warmed+packed+async decode rate over the pre-PR
        # continuous loop (which pays its XLA compiles inside the measured
        # window and syncs the host every token)
        "pipeline_decode_speedup": round(
            cont["decode_tok_per_s"] / max(cont_sync["decode_tok_per_s"],
                                           1e-9), 2),
        "paged_pool_pages": pool_pages,
        "paged_slot_gain": round(paged_probe["peak_live_slots"] /
                                 max(cont["peak_live_slots"], 1), 2),
        # decode-ladder gain: warmed paged engine with the auto bucket
        # ladder vs the same engine pinned at the full-capacity bucket
        "decode_ladder_speedup": round(
            paged["decode_tok_per_s"] /
            max(paged_full["decode_tok_per_s"], 1e-9), 2),
        "mean_decode_bucket": paged["mean_decode_bucket"],
        # tiered pool: forced-eviction probe (device pool barely above one
        # slot's horizon; everything else lives in the host tier)
        "tiered_device_pages": tiered["pool_pages"],
        "tiered_spills": tiered["pages_spilled"],
        "tiered_restores": tiered["pages_restored"],
        "tiered_parks": tiered["slots_parked"],
        # prefix sharing: one 2-page prefix stored once across 2*batch slots
        "prefix_shared_blocks": pre_sh["prefix_shared_blocks"],
        "prefix_peak_pages": pre_sh["peak_pages_in_use"],
        "prefix_slot_gain": round(pre_sh["peak_live_slots"] /
                                  max(pre_unsh["peak_live_slots"], 1), 2),
        "rows": rows,
    }
    ART.mkdir(exist_ok=True)
    out = ART / "serve_throughput.json"
    out.write_text(json.dumps(summary, indent=2) + "\n")

    print(f"arch={cfg.name} batch={args.batch} requests={n_req} "
          f"kv_keep={args.kv_keep} mesh={mesh_lib.mesh_desc(mesh)} "
          f"(compressed pool, {pool['kv_bytes_per_device']/1e3:.1f} kB KV "
          f"per device)")
    for r in rows:
        print(f"  {r['scheduler']:<15} batch={r['batch']} "
              f"steps={r['decode_steps']:<4} "
              f"slot_util={r['slot_utilization']:.2f} "
              f"peak_live={r['peak_live_slots']} "
              f"decode_tok/s={r['decode_tok_per_s']:.1f} "
              f"prefill={r['prefill_s']:.1f}s decode={r['decode_s']:.1f}s "
              f"host={r['host_s']:.1f}s warmup={r['warmup_s']:.1f}s "
              f"ttft_p50={r['ttft_p50_s']*1e3:.0f}ms "
              f"itl_p50={r['itl_p50_s']*1e3:.0f}ms wall={r['wall_s']:.1f}s")
    print(f"decode-step reduction continuous vs static: "
          f"{summary['step_reduction'] * 100:.0f}%")
    print(f"pipeline decode speedup (warmed+packed+async vs sync loop): "
          f"{summary['pipeline_decode_speedup']:.2f}x")
    print(f"paged: {pool_pages} pages (50% budget) on {2 * args.batch} slots "
          f"-> peak {paged_probe['peak_live_slots']} live "
          f"({summary['paged_slot_gain']:.2f}x dense), "
          f"{paged['slots_per_gb']:.0f} vs {cont['slots_per_gb']:.0f} slots/GB "
          f"-> {out}")
    print(f"decode ladder {paged['decode_buckets']}: mean bucket "
          f"{paged['mean_decode_bucket']:.1f}/{max_seq} tokens, "
          f"{summary['decode_ladder_speedup']:.2f}x vs full-capacity bucket")
    print(f"tiered: {tiered['pool_pages']} device + "
          f"{tiered['host_pool_pages']} host pages -> "
          f"{tiered['pages_spilled']} spilled / "
          f"{tiered['pages_restored']} restored, "
          f"{tiered['slots_parked']} parks (bitwise = untiered probe)")
    print(f"prefix sharing: {pre_sh['prefix_shared_blocks']} blocks by "
          f"reference, peak {pre_sh['peak_pages_in_use']} pages = 1x prefix "
          f"+ {2 * args.batch}x suffix -> peak_live "
          f"{pre_sh['peak_live_slots']} vs {pre_unsh['peak_live_slots']} "
          f"unshared ({summary['prefix_slot_gain']:.2f}x) at "
          f"{share_pages} pages")
    # sanity for CI: both schedulers must have served every token requested
    assert stat["requests"] == cont["requests"] == n_req
    assert cont["tokens_out"] == stat["tokens_out"] == cont_sync["tokens_out"]
    # pipeline acceptance: the warmed packed/async engine is a pure
    # scheduling change — greedy outputs bitwise identical to the pre-PR
    # synchronous loop on the same workload
    sync_done = engines_rows[1][1]
    dense_done = engines_rows[2][1]
    for a, b in zip(sync_done, dense_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    # with warmup excluded from the measured window, admission is cheap:
    # prefill wall share must sit below decode share on the warmed row,
    # and the decode rate must beat the sync loop (which pays compiles +
    # a per-token host sync inside decode_s) by >= 1.3x
    assert cont["prefill_s"] < cont["decode_s"], \
        (cont["prefill_s"], cont["decode_s"])
    assert summary["pipeline_decode_speedup"] >= 1.3, \
        summary["pipeline_decode_speedup"]
    # paged acceptance: bitwise greedy parity with the dense pool on the
    # mixed workload, and >= 1.5x concurrent slots at the 50% page budget
    paged_done = engines_rows[3][1]
    for a, b in zip(dense_done, paged_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert paged_probe["peak_live_slots"] >= 1.5 * cont["peak_live_slots"], \
        (paged_probe["peak_live_slots"], cont["peak_live_slots"])
    # decode-ladder acceptance: the bucketed engine is an exact slice of
    # the full-capacity step (bitwise tokens), actually dispatched below
    # capacity on this workload, and costs no throughput (host-side bucket
    # pick + smaller attends; interpret-mode CPU wall time is noisy, so
    # gate at >= 0.9x rather than demanding a CPU speedup)
    full_done = engines_rows[4][1]
    for a, b in zip(paged_done, full_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert paged["mean_decode_bucket"] < max_seq, paged["mean_decode_bucket"]
    assert summary["decode_ladder_speedup"] >= 0.9, \
        summary["decode_ladder_speedup"]
    # tiered acceptance: host offload actually happened (forced eviction on
    # the undersized device pool) and tokens are bitwise the untiered
    # probe's — the tier is a pure placement change for page content
    probe_done, tiered_done = engines_rows[5][1], engines_rows[6][1]
    for a, b in zip(probe_done, tiered_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert tiered["slots_parked"] > 0 and tiered["pages_spilled"] > 0, tiered
    assert tiered["pages_restored"] == tiered["pages_spilled"], tiered
    # prefix acceptance: the shared engine stores the prefix ONCE (peak
    # physical pages = 1x prefix + N x suffix horizon exactly), runs every
    # slot live at a budget where the unshared engine cannot, and its
    # tokens are bitwise the unshared engine's
    unsh_done, sh_done = engines_rows[7][1], engines_rows[8][1]
    for a, b in zip(unsh_done, sh_done):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert pre_sh["prefix_demotions"] == 0, pre_sh
    assert pre_sh["prefix_shared_blocks"] > 0, pre_sh
    assert pre_sh["peak_pages_in_use"] == pre_sh["pool_pages"], pre_sh
    assert pre_sh["peak_live_slots"] > pre_unsh["peak_live_slots"], \
        (pre_sh["peak_live_slots"], pre_unsh["peak_live_slots"])
    # zero-new-jit-traces under traffic holds for every warmed engine,
    # tiered fault path and prefix verification included
    for r in (cont, paged, paged_full, paged_probe, tiered, pre_unsh, pre_sh):
        assert r["new_traces"] == {}, (r["scheduler"], r["new_traces"])
    return summary


if __name__ == "__main__":
    main()
