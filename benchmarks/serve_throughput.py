"""Serving throughput: static lock-step vs continuous batching over the
compressed KV pool (qwen2_0_5b-shaped configs, CPU interpret mode).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--smoke] \
        [--mesh 4x1]

Emits benchmarks/artifacts/serve_throughput.json with tokens/s and
slot-utilization per scheduler. The point being measured: with per-slot
positions each pool slot is occupied exactly as long as its request lives
(the paper's dynamic feature-map buffer allocation, serving edition), so a
mixed workload finishes in fewer decode steps at higher slot utilization
than the wave-at-a-time baseline.

`--mesh DATAxMODEL` runs both schedulers on a host device mesh (slots on
data, heads on model) and records the mesh axis sizes plus the per-device
slice of the KV pool in the artifact — needs that many local devices (CI
forces 4 with XLA_FLAGS=--xla_force_host_platform_device_count=4).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.serve import engine as E

ART = pathlib.Path(__file__).parent / "artifacts"


def build_workload(cfg, n_requests: int, prompt_hi: int, new_hi: int, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(2, prompt_hi // 4), prompt_hi + 1))
        max_new = int(rng.integers(max(2, new_hi // 4), new_hi + 1))
        reqs.append(E.Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new))
    return reqs


def run_one(api, params, sc, batch, scheduler, workload_args):
    eng = E.Engine(api, params, sc, batch=batch, scheduler=scheduler)
    reqs = build_workload(api.cfg, *workload_args)
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    wall = time.perf_counter() - t0
    st = eng.stats
    # first token per request comes from prefill logits, not the decode loop
    dec_tok = st["tokens_out"] - st["requests"]
    return eng, {
        "scheduler": eng.scheduler,
        "requests": st["requests"],
        "tokens_out": st["tokens_out"],
        "decode_steps": st["steps"],
        "slot_utilization": round(eng.slot_utilization(), 4),
        "decode_s": round(st["decode_s"], 4),
        "prefill_s": round(st["prefill_s"], 4),
        "wall_s": round(wall, 4),
        "decode_tok_per_s": round(dec_tok / st["decode_s"], 2) if st["steps"] else 0.0,
        "tok_per_s": round(st["tokens_out"] / max(wall, 1e-9), 2),
        "mean_out_len": round(float(np.mean([len(r.out_tokens) for r in done])), 2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + workload (CI wiring check)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--kv-keep", type=int, default=8)
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serve mesh, e.g. 4x1 (default: none)")
    args = ap.parse_args(argv)

    cfg = get_config("qwen2_0_5b").reduced()
    api = model_api.build("qwen2_0_5b", cfg)
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    mesh = mesh_lib.make_serve_mesh(args.mesh)

    if args.smoke:
        n_req, prompt_hi, new_hi, max_seq = 5, 12, 6, 48
    else:
        n_req, prompt_hi, new_hi, max_seq = args.requests, 24, 16, 96

    sc = E.ServeConfig(max_seq=max_seq, kv_compress=True, kv_keep=args.kv_keep,
                       codec_backend="reference", mesh=mesh)
    workload = (n_req, prompt_hi, new_hi)

    engines_rows = [run_one(api, params, sc, args.batch, sched, workload)
                    for sched in ("static", "continuous")]
    rows = [row for _, row in engines_rows]

    stat, cont = rows
    # mesh provenance + the per-device slice of the sharded KV pool (the
    # banked-buffer accounting: what one "bank" actually holds)
    pool = engines_rows[0][0].kv_pool_stats()
    mesh_axes = {a: int(mesh.shape[a]) for a in mesh.axis_names} \
        if mesh is not None else None
    summary = {
        "arch": cfg.name,
        "batch": args.batch,
        "kv_keep": args.kv_keep,
        "max_seq": max_seq,
        "smoke": bool(args.smoke),
        "mesh": mesh_axes,
        "kv_pool_bytes": pool["kv_pool_bytes"],
        "kv_bytes_per_device": round(pool["kv_bytes_per_device"], 1),
        "step_reduction": round(
            1.0 - cont["decode_steps"] / max(stat["decode_steps"], 1), 4),
        "rows": rows,
    }
    ART.mkdir(exist_ok=True)
    out = ART / "serve_throughput.json"
    out.write_text(json.dumps(summary, indent=2) + "\n")

    print(f"arch={cfg.name} batch={args.batch} requests={n_req} "
          f"kv_keep={args.kv_keep} mesh={mesh_lib.mesh_desc(mesh)} "
          f"(compressed pool, {pool['kv_bytes_per_device']/1e3:.1f} kB KV "
          f"per device)")
    for r in rows:
        print(f"  {r['scheduler']:<11} steps={r['decode_steps']:<4} "
              f"slot_util={r['slot_utilization']:.2f} "
              f"decode_tok/s={r['decode_tok_per_s']:.1f} wall={r['wall_s']:.1f}s")
    print(f"decode-step reduction continuous vs static: "
          f"{summary['step_reduction'] * 100:.0f}%  -> {out}")
    # sanity for CI: both schedulers must have served every token requested
    assert stat["requests"] == cont["requests"] == n_req
    assert cont["tokens_out"] == stat["tokens_out"]
    return summary


if __name__ == "__main__":
    main()
