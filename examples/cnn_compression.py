"""Paper reproduction in miniature: run VGG-16-BN over a 1/f image with the
interlayer compression enabled, printing the per-fusion-layer ratios
(paper Table III) and the SRAM flip-storage utilization (paper Fig. 5).

    PYTHONPATH=src python examples/cnn_compression.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor, encode
from repro.data.synthetic import natural_images
from repro.models import cnn

img = jnp.asarray(natural_images(0, 1, 96, 96))
params = cnn.vgg16_bn_init(jax.random.PRNGKey(1))
sched = cnn.CompressionSchedule(n_layers=10)
stats = cnn.FusionStats()
logits = cnn.vgg16_bn_apply(params, img, sched, stats)

print("fusion-layer compression (paper Table III analogue):")
for l in stats.layers[:10]:
    r = l["comp_bits"] / l["orig_bits"]
    print(f"  layer {l['idx']:2d} {str(l['shape']):22s} -> {float(r)*100:5.1f}% of dense")
print(f"overall (first 10): {float(stats.overall_ratio())*100:.1f}%")

# flip-storage utilization of the paper's 8-bank SRAM (Fig. 5)
fmap = jnp.transpose(img, (0, 3, 1, 2))
comp = compressor.compress(fmap, compressor.CompressionPolicy(level=1))
idx = np.asarray(comp.index).reshape(-1, 8, 8)
u_flip = encode.sram_utilization(idx, flip=True)
u_noflip = encode.sram_utilization(idx, flip=False)
print(f"\nSRAM bank utilization: flip {u_flip*100:.1f}% vs no-flip {u_noflip*100:.1f}% "
      f"(the paper's Fig. 5 packing argument)")
assert u_flip >= u_noflip
print("cnn_compression example OK")
