"""Quickstart: the paper's interlayer feature-map compression in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the full paper pipeline on a single feature map, then shows the three
TPU deployment hooks (ActCompress / KVCompress / GradCompress) in miniature.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compressor
from repro.core.activation import compressed_checkpoint
from repro.data.synthetic import natural_images

# --- 1. the paper pipeline on one "interlayer feature map" ----------------
fmap = jnp.asarray(natural_images(seed=0, batch=1, h=64, w=64, c=1))[0, :, :, 0]

for level in range(4):  # the paper's 2-bit quantization-level register
    policy = compressor.CompressionPolicy(level=level)
    comp = compressor.compress(fmap, policy)
    ratio = float(compressor.compression_ratio(comp))
    rec = compressor.decompress(comp)
    err = float(jnp.linalg.norm(rec - fmap) / jnp.linalg.norm(fmap))
    print(f"level {level}: stored at {ratio*100:5.1f}% of 16-bit dense, "
          f"reconstruction error {err:.4f}")

# --- 2. the TPU runtime path: structured frequency truncation --------------
comp_t = compressor.compress_truncated(fmap, keep=4)
print(f"\ntruncated path: {comp_t.nbytes_per_element():.3f} B/elem "
      f"(vs 2 B bf16 = {2/comp_t.nbytes_per_element():.1f}x), "
      f"err {float(jnp.linalg.norm(compressor.decompress_truncated(comp_t) - fmap) / jnp.linalg.norm(fmap)):.4f}")

# --- 3. ActCompress: residuals saved for backward in compressed form ------
w = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.05


def layer(p, x):
    return x + jnp.tanh(x @ p)


wrapped = compressed_checkpoint(layer, keep=4)
x = jnp.asarray(natural_images(1, 8, 8, 64, c=1))[..., 0].reshape(8, 8, 64)
g_comp = jax.grad(lambda p: wrapped(p, x).sum())(w)
g_exact = jax.grad(lambda p: layer(p, x).sum())(w)
cos = float((g_comp * g_exact).sum() /
            (jnp.linalg.norm(g_comp) * jnp.linalg.norm(g_exact)))
print(f"\nActCompress gradient vs exact: cosine {cos:.4f} "
      f"(residual stored at {(4*4+8)/64/2*100:.0f}% of bf16)")

print("\nquickstart OK")
