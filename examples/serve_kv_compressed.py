"""Serve a small LM with continuous batching, comparing raw vs DCT-compressed
KV cache (the paper's feature-map buffer, reinterpreted for decoding).

Requests with different prompt lengths and token budgets stream through 4
slots: a slot retires the moment its request finishes and is immediately
re-admitted from the queue — the pool is occupied per request, like the
paper's dynamically allocated feature-map buffer.

    PYTHONPATH=src python examples/serve_kv_compressed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import api as model_api
from repro.serve import engine as E

arch = "yi_6b"
cfg = get_config(arch).reduced()
api = model_api.build(arch, cfg)
params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
rng = np.random.default_rng(0)

# 6 requests over 4 slots, mixed prompt lengths and budgets
plens = [12, 5, 19, 9, 14, 7]
budgets = [16, 6, 10, 14, 8, 12]
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in plens]

outs = {}
for compress in (False, True):
    sc = E.ServeConfig(max_seq=96, kv_compress=compress, kv_keep=8)
    eng = E.Engine(api, params, sc, batch=4)
    reqs = [E.Request(uid=i, prompt=p.copy(), max_new=b)
            for i, (p, b) in enumerate(zip(prompts, budgets))]
    done = eng.generate(reqs)
    outs[compress] = [r.out_tokens for r in done]
    label = "compressed" if compress else "raw       "
    print(f"{label} kv: steps={eng.stats['steps']} "
          f"slot_util={eng.slot_utilization():.2f} req0 tokens {outs[compress][0]}")

agree = np.mean([
    np.mean(np.asarray(a[:len(b)]) == np.asarray(b[:len(a)]))
    for a, b in zip(outs[False], outs[True])
])
print(f"\ntoken agreement raw vs keep=8 compressed cache: {agree*100:.0f}%")
print(f"cache bytes/token/layer: raw {4*cfg.n_kv_heads*cfg.head_dim:.0f} "
      f"vs compressed {2*cfg.n_kv_heads*(cfg.head_dim//8)*(64+4)/8:.0f} (keep=8)")
print("serve example OK")
