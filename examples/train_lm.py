"""End-to-end driver: train a ~10M-parameter LM (CPU-sized; the identical driver scales to any config) for a few hundred steps
with the full production stack — sharded state, microbatched step,
ActCompress remat, checkpointing, auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is a thin veneer over the real launcher (repro.launch.train); every
flag it passes works the same on a TPU fleet.
"""
import argparse
import sys

from repro.launch import train as train_launch

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2_0_5b")
    args = ap.parse_args()
    losses = train_launch.main([
        "--arch", args.arch,
        "--reduced",                 # ~100M-class on CPU
        "--steps", str(args.steps),
        "--seq", "256",
        "--batch", "16",
        "--microbatches", "2",
        "--remat", "compressed",     # the paper's technique on the residuals
        "--save-every", "100",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
    ])
    assert losses[-1] < losses[0], "training must reduce loss"
    print("train_lm example OK")
