"""Sharded checkpointing: atomic, retained, async, resumable.

Layout (one directory per step):

    <dir>/step_000420/
        meta.json                 # step, timestamp, tree manifest, dp size
        proc_000.npz              # this process's addressable leaf shards
        _COMMITTED                # written LAST -> crash-safe commit marker

Multi-host protocol: every process writes only its addressable shards
(`leaf.addressable_shards`), process 0 writes meta + the commit marker after
a barrier. On this single-process container that degenerates to one npz with
full arrays — same code path, no special casing.

Restore re-shards to whatever mesh the restart runs on (elastic restarts:
the dp size may have changed; `jax.make_array_from_callback` reads the
saved global array and lays it out per the NEW sharding).

Async save: `save_async` snapshots to host RAM (device_get) synchronously —
cheap — and does the file I/O on a worker thread so the train loop never
blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

COMMIT = "_COMMITTED"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def save(root: str, step: int, state: Any, *, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    host_state = jax.device_get(state)
    return _write(root, step, host_state, keep=keep)


_ASYNC_LOCK = threading.Lock()
_PENDING: list[threading.Thread] = []


def save_async(root: str, step: int, state: Any, *, keep: int = 3) -> threading.Thread:
    """Device->host snapshot now; disk I/O on a daemon thread."""
    host_state = jax.device_get(state)  # snapshot before params mutate

    def work():
        with _ASYNC_LOCK:  # serialize writers; last-step-wins retention
            _write(root, step, host_state, keep=keep)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _write(root: str, step: int, host_state: Any, *, keep: int) -> str:
    proc = jax.process_index()
    final = _step_dir(root, step)
    tmp = final + f".tmp{proc}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(host_state)
    # bfloat16 has no stable npy representation -> store as a u16 bit view
    # (restore() re-views based on the target leaf dtype; zero size overhead)
    def _np(v):
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)
        return a

    arrays = {k: _np(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"proc_{proc:03d}.npz"), **arrays)
    if proc == 0:
        meta = {
            "step": step,
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "nprocs": jax.process_count(),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
    # commit: rename tmp -> final, then marker (rename is atomic on POSIX)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(final, COMMIT), "w") as f:
        f.write(str(step))
    _apply_retention(root, keep)
    return final


def _apply_retention(root: str, keep: int):
    steps = committed_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


def committed_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp0"):
            d = os.path.join(root, name)
            if os.path.exists(os.path.join(d, COMMIT)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = committed_steps(root)
    return steps[-1] if steps else None


def restore(root: str, like: Any, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, int]:
    """Load `step` (default latest) re-sharded like `shardings` (or on the
    current default device). `like` provides the pytree structure/dtypes."""
    step = step if step is not None else latest_step(root)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = _step_dir(root, step)
    data: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(d)):
        if name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                data.update({k: z[k] for k in z.files})
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_flat = jax.tree.leaves(shardings) if shardings is not None else None
    for i, (path, leaf) in enumerate(flat_like[0]):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if leaf.dtype == jnp.bfloat16 and arr.dtype == np.uint16:
            arr = arr.view(jnp.bfloat16)
        if shard_flat is not None:
            leaves.append(jax.make_array_from_callback(
                arr.shape, shard_flat[i], lambda idx, a=arr: a[idx]
            ))
        else:
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), step
