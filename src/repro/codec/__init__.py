"""repro.codec — unified feature-map codec with a pluggable backend registry.

The single seam between the paper's compression algorithms and their
implementations: `reference` (pure-JAX einsum, runs and differentiates
anywhere) and `pallas` (fused TPU kernels, the default on TPU; interpret
mode on CPU).  See `repro.codec.api` for the schemes and
`repro.codec.dispatch` for selection rules (env: REPRO_CODEC_BACKEND,
REPRO_CODEC_INTERPRET).
"""
from repro.codec import dispatch
from repro.codec import families
from repro.codec import plan
from repro.codec.api import (
    BLOCK,
    Codec,
    Compressed,
    CompressionPolicy,
    TruncatedCompressed,
    compress,
    compress_blocks,
    compression_ratio,
    dct2,
    decompress,
    decompress_blocks,
    idct2,
    paper_compress,
    paper_decompress,
    paper_masked_values,
    paper_roundtrip,
    paper_storage_bits,
    quant_pack,
    roundtrip,
    storage_stats,
)
from repro.codec.families import (
    CodecFamily,
    PlaneSpec,
    available_families,
    get_family,
    register_family,
)
from repro.codec.plan import CompressionPlan, LayerPolicy, as_plan
from repro.codec.dispatch import (
    available_backends,
    get_backend,
    register_backend,
    resolve_backend_name,
    resolve_interpret,
    set_default_backend,
)
from repro.codec.reference import ReferenceBackend


def _pallas_factory():
    # Deferred: importing the Pallas backend pulls jax.experimental.pallas and
    # all three kernel modules — reference-only consumers (CPU) never pay it.
    from repro.codec.pallas_backend import PallasBackend

    return PallasBackend()


register_backend("reference", ReferenceBackend)
register_backend("pallas", _pallas_factory)


def __getattr__(name):
    if name == "PallasBackend":
        from repro.codec.pallas_backend import PallasBackend

        return PallasBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BLOCK",
    "Codec",
    "CodecFamily",
    "Compressed",
    "CompressionPlan",
    "CompressionPolicy",
    "LayerPolicy",
    "PallasBackend",
    "PlaneSpec",
    "ReferenceBackend",
    "TruncatedCompressed",
    "as_plan",
    "available_backends",
    "available_families",
    "compress",
    "compress_blocks",
    "compression_ratio",
    "dct2",
    "decompress",
    "decompress_blocks",
    "dispatch",
    "families",
    "get_backend",
    "get_family",
    "idct2",
    "paper_compress",
    "paper_decompress",
    "paper_masked_values",
    "paper_roundtrip",
    "paper_storage_bits",
    "plan",
    "quant_pack",
    "register_backend",
    "register_family",
    "resolve_backend_name",
    "resolve_interpret",
    "roundtrip",
    "set_default_backend",
    "storage_stats",
]
