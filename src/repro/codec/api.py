"""Unified feature-map codec API (paper §III, DESIGN.md §2).

Every consumer of feature-map compression in the repo — the paper-exact CNN
pipeline, ActCompress checkpointing, the compressed KV cache, serving, and
the benchmarks — routes through this module.  It owns the shared
boilerplate the per-kernel ``ops.py`` shims used to duplicate (8-alignment
padding, leading-dim folding, backend/interpret selection) and dispatches
the actual math to a registered backend (`reference` pure-JAX einsum or
`pallas` fused kernels; see `repro.codec.dispatch`).

Two schemes, matching the two pipelines the paper describes:

* **truncated** (TPU runtime path): fused DCT -> keep the k x k
  low-frequency corner -> per-tile symmetric int8.  Fixed shapes, usable
  inside jit/scan/custom_vjp.  `Codec` / `compress` / `decompress` /
  `roundtrip` / `storage_stats`, with `compress_blocks`/`decompress_blocks`
  as the container-free layer for consumers that manage their own storage
  (the KV cache).
* **paper** (bit-faithful pipeline, Eq. 2-10 + Fig. 5): DCT -> min-max
  m-bit quant -> Q-table quant -> bitmap index.  `paper_compress` /
  `paper_decompress` / `paper_roundtrip` / `compression_ratio`.

Leading dims: all entry points take ``(..., H, W)`` and work per trailing
plane.  After padding H to a multiple of 8, leading dims are folded into the
row axis (exact for 8x8 tiling — no block straddles a fold boundary), so a
whole ``(N, C, H, W)`` activation batch is one backend call, not an N*C
Python loop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import dispatch
from repro.core import dct as dct_lib
from repro.core import encode as encode_lib
from repro.core import quantize as quant_lib

BLOCK = 8

# Per-tile storage header: the f32 scale is the ONLY header the truncated
# scheme stores (the symmetric quantizer guarantees the `zero` plane is
# all-zeros layout filler — see TruncatedCompressed).  Every storage report
# in the repo (TruncatedCompressed.nbytes_per_element, Codec.storage_stats,
# CompressionPlan.kv_bytes_per_token, KVSegment.nbytes and the serve
# engine's kv_pool_stats) derives from `tile_bytes` so the accounting can't
# drift between the codec and the pool again.
TILE_HEADER_BYTES = 4


def tile_bytes(keep: int) -> int:
    """Compressed bytes of one 8x8 tile: int8 k x k corner + f32 scale."""
    return keep * keep + TILE_HEADER_BYTES


# ---------------------------------------------------------------------------
# Policies and compressed containers (canonical home; repro.core.compressor
# re-exports these names for backward compatibility)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CompressionPolicy:
    """Per-layer policy (paper: 2-bit level register + compressed-layer set)."""

    level: int = 1          # 0 aggressive ... 3 gentle (paper's 4 levels)
    bits: int = 8           # step-1 integer precision m
    enabled: bool = True

    def keep(self) -> int:
        return quant_lib.level_to_keep(self.level)


@jax.tree_util.register_pytree_node_class
@dataclass
class Compressed:
    """Paper-exact compressed representation of a (..., H, W) tensor."""

    values: jax.Array      # (..., nh, nw, 8, 8) quantized coefficients (int32)
    index: jax.Array       # same shape, bool
    fmin: jax.Array
    fmax: jax.Array
    level: int
    bits: int
    orig_hw: tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.index, self.fmin, self.fmax), (
            self.level,
            self.bits,
            self.orig_hw,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, index, fmin, fmax = children
        level, bits, orig_hw = aux
        return cls(values, index, fmin, fmax, level, bits, orig_hw)


@jax.tree_util.register_pytree_node_class
@dataclass
class TruncatedCompressed:
    """(..., nh, nw, k, k) int8 low-frequency corners + per-tile scale.

    `zero` is retained for layout compatibility with the original runtime
    container; the codec always writes (and assumes) zeros there — the
    truncated scheme quantizes symmetrically.
    """

    coefs: jax.Array       # int8
    scale: jax.Array       # (..., nh, nw, 1, 1) f32
    zero: jax.Array        # (..., nh, nw, 1, 1) f32 (always zeros)
    keep: int
    orig_hw: tuple[int, int]

    def tree_flatten(self):
        return (self.coefs, self.scale, self.zero), (self.keep, self.orig_hw)

    @classmethod
    def tree_unflatten(cls, aux, children):
        coefs, scale, zero = children
        keep, orig_hw = aux
        return cls(coefs, scale, zero, keep, orig_hw)

    def nbytes_per_element(self) -> float:
        """Compressed bytes per original element (the runtime ratio).

        The header is the f32 scale only: the `zero` plane is guaranteed
        zero by the symmetric quantizer (it exists purely for layout
        compatibility), so charging for it would overstate the footprint.
        """
        return tile_bytes(self.keep) / (BLOCK * BLOCK)


# ---------------------------------------------------------------------------
# Container-free blocks layer (consumers with their own storage: KV cache)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("keep", "backend"))
def _compress_blocks(x, keep, backend):
    b = dispatch.get_backend(backend)
    *lead, r, c = x.shape
    q, scale = b.compress_plane(x.reshape(-1, c), keep)
    nh, nw = r // BLOCK, c // BLOCK
    return (
        q.reshape(*lead, nh, nw, keep, keep),
        scale.reshape(*lead, nh, nw),
    )


def compress_blocks(x: jax.Array, keep: int, backend: str | None = None):
    """(..., R, C) with R % 8 == C % 8 == 0 -> fused DCT+truncate+int8.

    Returns (coefs (..., R/8, C/8, k, k) int8, scale (..., R/8, C/8) f32).
    """
    *_, r, c = x.shape
    if r % BLOCK or c % BLOCK:
        raise ValueError(f"plane dims must be multiples of {BLOCK}, got {(r, c)}")
    return _compress_blocks(x, keep, dispatch.resolve_backend_name(backend))


@functools.partial(jax.jit, static_argnames=("out_dtype", "backend"))
def _decompress_blocks(q, scale, out_dtype, backend):
    b = dispatch.get_backend(backend)
    *lead, nh, nw, k, _ = q.shape
    out = b.decompress_plane(q.reshape(-1, nw, k, k), scale.reshape(-1, nw),
                             out_dtype=out_dtype)
    return out.reshape(*lead, nh * BLOCK, nw * BLOCK)


def decompress_blocks(q: jax.Array, scale: jax.Array, out_dtype=jnp.float32,
                      backend: str | None = None) -> jax.Array:
    """Inverse of `compress_blocks` -> (..., R, C)."""
    return _decompress_blocks(q, scale, out_dtype,
                              dispatch.resolve_backend_name(backend))


# ---------------------------------------------------------------------------
# Blocked 8x8 DCT/IDCT dispatch (any leading dims; trailing dims 8-aligned)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("inverse", "backend"))
def _dct2(x, inverse, backend):
    b = dispatch.get_backend(backend)
    shape = x.shape
    out = b.dct2_plane(x.reshape(-1, shape[-1]), inverse=inverse)
    return out.reshape(shape)


def dct2(x: jax.Array, inverse: bool = False, backend: str | None = None) -> jax.Array:
    """Blocked 8x8 2-D DCT (or IDCT) over the trailing two dims."""
    *_, r, c = x.shape
    if r % BLOCK or c % BLOCK:
        raise ValueError(f"plane dims must be multiples of {BLOCK}, got {(r, c)}")
    return _dct2(x, inverse, dispatch.resolve_backend_name(backend))


def idct2(x: jax.Array, backend: str | None = None) -> jax.Array:
    return dct2(x, inverse=True, backend=backend)


# ---------------------------------------------------------------------------
# Paper-exact quantize + bitmap index dispatch (Eq. 7-8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("level", "bits", "backend"))
def _quant_pack(x, fmin, fmax, level, bits, backend):
    b = dispatch.get_backend(backend)
    shape = x.shape
    q2, idx, nnz = b.quant_pack_plane(x.reshape(-1, shape[-1]), fmin, fmax,
                                      level, bits=bits)
    return q2.reshape(shape), idx.reshape(shape), nnz


def quant_pack(x: jax.Array, fmin, fmax, level: int = 1, bits: int = 8,
               backend: str | None = None):
    """Two-step quantization + 1-bit index of aligned (..., R, C) coefficients.

    Returns (q2 int32, index int8, nnz int32 scalar).
    """
    return _quant_pack(x, fmin, fmax, level, bits,
                       dispatch.resolve_backend_name(backend))


# ---------------------------------------------------------------------------
# Truncated scheme: the Codec facade
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Codec:
    """Runtime feature-map codec: DCT-truncated int8 with pluggable backends.

    `backend=None` auto-selects per `repro.codec.dispatch` (fused Pallas on
    TPU, pure-JAX reference elsewhere). The reference backend is the one to
    force when gradients must flow *through* the codec (the Pallas kernels
    define no VJP); ActCompress never differentiates through it, so the
    default is safe there.
    """

    keep: int = 4
    backend: str | None = None

    def compress(self, x: jax.Array) -> TruncatedCompressed:
        """(..., H, W) -> int8 k x k corners; edge-pads H, W to 8-multiples."""
        *_, h, w = x.shape
        padded, _ = dct_lib.pad_to_block(x)
        q, scale = compress_blocks(padded, self.keep, backend=self.backend)
        scale = scale[..., None, None]
        return TruncatedCompressed(
            coefs=q, scale=scale, zero=jnp.zeros_like(scale),
            keep=self.keep, orig_hw=(h, w),
        )

    def decompress(self, c: TruncatedCompressed, dtype=jnp.float32) -> jax.Array:
        x = decompress_blocks(c.coefs, c.scale[..., 0, 0], jnp.float32,
                              backend=self.backend)
        return dct_lib.crop_from_block(x, c.orig_hw).astype(dtype)

    def roundtrip(self, x: jax.Array) -> jax.Array:
        """Lossy reconstruct — what the next layer actually consumes."""
        return self.decompress(self.compress(x), x.dtype)

    def storage_stats(self, c: TruncatedCompressed,
                      orig_value_bits: int = 16) -> dict[str, float]:
        """Static storage accounting (no device work): bits, ratio, B/elem.

        Counts the f32 scale as the only per-tile header — the always-zero
        `zero` plane is layout filler, not storage (see TruncatedCompressed).
        """
        ntiles = int(np.prod(c.coefs.shape[:-2]))
        comp_bits = ntiles * tile_bytes(c.keep) * 8  # int8 corner + f32 scale
        h, w = c.orig_hw
        lead = int(np.prod(c.coefs.shape[:-4])) if c.coefs.ndim > 4 else 1
        orig_bits = lead * h * w * orig_value_bits
        return {
            "compressed_bits": float(comp_bits),
            "orig_bits": float(orig_bits),
            "ratio": comp_bits / orig_bits,
            "bytes_per_element": c.nbytes_per_element(),
        }


def compress(x: jax.Array, keep: int = 4, backend: str | None = None) -> TruncatedCompressed:
    return Codec(keep=keep, backend=backend).compress(x)


def decompress(c: TruncatedCompressed, dtype=jnp.float32,
               backend: str | None = None) -> jax.Array:
    return Codec(keep=c.keep, backend=backend).decompress(c, dtype)


def roundtrip(x: jax.Array, keep: int = 4, backend: str | None = None) -> jax.Array:
    return Codec(keep=keep, backend=backend).roundtrip(x)


def storage_stats(c: TruncatedCompressed, orig_value_bits: int = 16) -> dict[str, float]:
    return Codec(keep=c.keep).storage_stats(c, orig_value_bits)


# ---------------------------------------------------------------------------
# Paper scheme (Eq. 2-10 + Fig. 5 bitmap encode)
# ---------------------------------------------------------------------------

def paper_compress(x: jax.Array, policy: CompressionPolicy,
                   backend: str | None = None) -> Compressed:
    """Paper pipeline: pad -> DCT -> quant x2 -> bitmap encode."""
    *_, h, w = x.shape
    padded, _ = dct_lib.pad_to_block(x)
    coefs = dct2(padded, backend=backend)
    fmin, fmax = quant_lib.compute_range(coefs)
    q2, idx, _ = quant_pack(coefs, fmin, fmax, policy.level, policy.bits,
                            backend=backend)
    return Compressed(
        values=dct_lib._blockize(q2),
        index=dct_lib._blockize(idx).astype(bool),
        fmin=fmin,
        fmax=fmax,
        level=policy.level,
        bits=policy.bits,
        orig_hw=(h, w),
    )


def paper_masked_values(c: Compressed) -> jax.Array:
    """The carrier gated by the 1-bit index matrix — the only sanctioned way
    to read a `Compressed`'s coefficients.

    In the paper's hardware only non-zero values are ever written to the
    feature-map buffer, so the payload under a zero index bit is GARBAGE by
    contract (encode.py documents the same for our dense carrier).  Every
    decode and every nnz-based accounting must read through this gate;
    tests/test_codec.py pins decode invariance to corrupted masked lanes.
    """
    return jnp.where(c.index, c.values, 0)


def paper_decompress(c: Compressed, dtype=jnp.float32,
                     backend: str | None = None) -> jax.Array:
    """Inverse: decode -> inverse quant x2 -> IDCT -> crop."""
    # gate by the index matrix BEFORE any arithmetic touches the carrier —
    # a Compressed rebuilt from the real sparse stream has garbage lanes
    q2 = encode_lib.decode_blocks(
        encode_lib.EncodedBlocks(values=paper_masked_values(c), index=c.index)
    )
    params = quant_lib.QuantParams(fmin=c.fmin, fmax=c.fmax, bits=c.bits)
    coefs = quant_lib.dequantize_blocks(q2, params, c.level)
    x = idct2(dct_lib._unblockize(coefs), backend=backend)
    return dct_lib.crop_from_block(x, c.orig_hw).astype(dtype)


def paper_roundtrip(x: jax.Array, policy: CompressionPolicy,
                    backend: str | None = None) -> jax.Array:
    return paper_decompress(paper_compress(x, policy, backend), x.dtype, backend)


def paper_storage_bits(c: Compressed) -> jax.Array:
    """Exact compressed bit count: 64 index bits per block + `bits` per
    non-zero (the per-tensor fmin/fmax header is negligible and ignored, as
    in the paper)."""
    nblocks = c.index.size // (BLOCK * BLOCK)
    return nblocks * BLOCK * BLOCK + jnp.sum(c.index) * c.bits


def compression_ratio(c: Compressed, orig_value_bits: int = 16) -> jax.Array:
    """Paper Eq. 20: compressed bits / original bits (lower = better)."""
    h, w = c.orig_hw
    lead = int(np.prod(c.values.shape[:-4])) if c.values.ndim > 4 else 1
    orig_bits = lead * h * w * orig_value_bits
    return paper_storage_bits(c) / orig_bits
