"""Backend dispatch for the unified feature-map codec (`repro.codec`).

One seam for every decision the old per-kernel ``ops.py`` shims each made on
their own: which backend implements a transform (pure-JAX ``reference`` vs
fused Pallas), whether a Pallas call compiles or interprets, and how
arbitrary ``(..., H, W)`` tensors are folded into the 2-D planes the kernels
consume.

Backend selection order (first hit wins):
  1. an explicit ``backend=`` argument at the call site
  2. a process-wide override installed with `set_default_backend`
  3. the ``REPRO_CODEC_BACKEND`` environment variable
  4. auto: ``"pallas"`` when ``jax.default_backend() == "tpu"``, else
     ``"reference"`` (the einsum path, which also differentiates).

Interpret-mode selection (consulted by the Pallas backend only): compiled on
TPU, interpret elsewhere (CPU CI), overridable with
``REPRO_CODEC_INTERPRET=0/1``.
"""
from __future__ import annotations

import os
from typing import Callable

import jax

ENV_BACKEND = "REPRO_CODEC_BACKEND"
ENV_INTERPRET = "REPRO_CODEC_INTERPRET"

_REGISTRY: dict[str, Callable[[], object]] = {}
_INSTANCES: dict[str, object] = {}
_default_override: str | None = None


def register_backend(name: str, factory: Callable[[], object]) -> None:
    """Register a backend factory under `name` (later wins, instance reset)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def set_default_backend(name: str | None) -> None:
    """Process-wide backend override; `None` restores auto selection."""
    global _default_override
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown codec backend {name!r}; have {available_backends()}")
    _default_override = name


def resolve_backend_name(name: str | None = None) -> str:
    """Resolve `name` (None = auto) to a concrete backend name.

    Resolution happens OUTSIDE jit boundaries so the chosen name can ride as
    a static argument and the env/override is re-read on every call.
    """
    if name is not None:
        return name
    if _default_override is not None:
        return _default_override
    env = os.environ.get(ENV_BACKEND)
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def get_backend(name: str | None = None):
    name = resolve_backend_name(name)
    if name not in _REGISTRY:
        raise KeyError(f"unknown codec backend {name!r}; have {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Pallas kernels compile on TPU and interpret elsewhere unless forced."""
    if interpret is not None:
        return interpret
    env = os.environ.get(ENV_INTERPRET)
    if env in ("0", "1"):
        return env == "1"
    return jax.default_backend() != "tpu"
