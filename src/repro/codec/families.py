"""Codec families: the declared plane tree behind every compressed KV store.

A `CodecFamily` is the storage-geometry contract between the codec and every
consumer that holds compressed blocks — the KV cache containers, the paged
pool, the sharding rules, the tiered host mirror, and the plan's byte
accounting.  A family declares

  * its PLANE TREE: named per-block planes with dtypes and shapes
    (`plane_specs`), from which the cache layouts derive every array they
    allocate — dense stores prepend ``(Lseg, B, S/8, Hkv)``, the paged pool
    ``(Lseg, P, Hkv)``, and each plane is materialized once for K and once
    for V as ``{name}_k`` / ``{name}_v``;
  * a lossless PACK/UNPACK seam over the quantized DCT coefficients:
    ``pack(q, scale)`` lays int8 tile corners + per-tile scales out into the
    declared planes, ``unpack(planes)`` reconstructs them bitwise (scales
    may be lossy where a family declares an adaptive header, the int8
    blocks never are — pinned by property tests);
  * byte accounting, BOTH ways: ``analytic_tile_bytes`` is the data-
    independent worst case the plan/pool budgets charge, and
    ``measured_tile_bits`` is the data-dependent footprint of what a tile
    actually stored — analytic always upper-bounds measured.

Every family must declare a ``packed`` carrier plane of block shape
``(hd/8, k, k)`` int8: fixed worst-case capacity keeps every cache shape
static under jit (the EBPC payload is front-packed into it and its real
length rides the ``blen`` scalar plane), and gives the containers one
uniform plane to read pool geometry (page count, max_seq) from.

Registered families:

  * ``dct``      — the paper's truncated scheme exactly as before the
                   refactor: int8 k x k corner + f32 scale. Plane names and
                   shapes are bit-for-bit the pre-refactor layout, so the
                   refactored path is bitwise identical (pinned in tests).
  * ``bitplane`` — EBPC-style (arxiv 1908.11645) storage of the quantized
                   coefficients: a 1-bit nonzero map packed 8/byte
                   (``bpmask``), the nonzeros front-packed into the fixed
                   carrier, and a per-tile measured length (``blen``) that
                   agrees EXACTLY with `core.encode.rle_codec_bits` — the
                   repo's one RLE accounting, reused, not reimplemented.
  * ``asc``      — adaptive-scale compression (arxiv 2312.08176 flavour):
                   the 4-byte f32 scale header is replaced by a 1-byte
                   log2-exponent selected per block (``sexp``), trading a
                   bounded scale error (< 2**(1/16)-1 per tile) for a
                   smaller fixed footprint.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.codec import api as codec_api
from repro.core import encode as encode_lib

BLOCK = 8
# f32 per-tile scale header charged by dct/bitplane (== api.TILE_HEADER_BYTES)
SCALE_HEADER_BYTES = codec_api.TILE_HEADER_BYTES


@dataclass(frozen=True)
class PlaneSpec:
    """One declared plane: cache arrays are ``prefix + block_shape``.

    `block_shape` is everything after the cache's block axis prefix —
    ``(Lseg, B, S/8, Hkv)`` dense, ``(Lseg, P, Hkv)`` paged — so its first
    dim is the per-head tile count hd/8 and the rest are per-tile dims.
    `tile_shape` (block_shape[1:]) is what `pack` emits per tile.
    """

    name: str
    dtype: object
    block_shape: tuple[int, ...]


class CodecFamily:
    """Base contract; subclasses fill in the plane tree and pack/unpack.

    `pack`/`unpack` take/return the quantized-block form the block codec
    (`codec.api.compress_blocks`) produces: ``q (..., k, k) int8`` with one
    ``scale (...)`` f32 per tile, any leading dims.  They are pure layout —
    all DCT/quantization math stays in the backend dispatch, so one fused
    kernel serves every family.
    """

    name: str = ""
    # only the dct layout matches what the fused pallas attend kernel reads;
    # other families decode through the reference attend scan.
    supports_fused_attend: bool = False

    def plane_specs(self, keep: int, head_dim: int) -> tuple[PlaneSpec, ...]:
        raise NotImplementedError

    def pack(self, q, scale, keep: int) -> dict:
        raise NotImplementedError

    def unpack(self, planes: dict, keep: int):
        raise NotImplementedError

    def analytic_tile_bytes(self, keep: int) -> int:
        """Data-independent worst-case bytes of one stored 8x8 tile
        (headers included) — what plan budgets and pool sizing charge."""
        raise NotImplementedError

    def measured_tile_bits(self, q) -> jnp.ndarray:
        """Measured storage bits per tile (headers included) for quantized
        blocks ``q (..., k, k)`` -> ``(...)`` int32.  Data-dependent for
        variable-length families; always <= 8 * analytic_tile_bytes."""
        raise NotImplementedError

    # ---- convenience entry points over the block codec ------------------
    def compress(self, x, keep: int, backend: str | None = None) -> dict:
        """(..., S, hd) -> planes dict (block layout, see plane_specs)."""
        q, scale = codec_api.compress_blocks(x, keep, backend=backend)
        return self.pack(q, scale, keep)

    def decompress(self, planes: dict, keep: int, dtype=jnp.float32,
                   backend: str | None = None):
        q, scale = self.unpack(planes, keep)
        return codec_api.decompress_blocks(q, scale, out_dtype=dtype,
                                           backend=backend)


# ---------------------------------------------------------------------------
# dct — the pre-refactor layout, verbatim
# ---------------------------------------------------------------------------

class DctFamily(CodecFamily):
    name = "dct"
    supports_fused_attend = True

    def plane_specs(self, keep, head_dim):
        nh = head_dim // BLOCK
        return (PlaneSpec("packed", jnp.int8, (nh, keep, keep)),
                PlaneSpec("scale", jnp.float32, (nh,)))

    def pack(self, q, scale, keep):
        return {"packed": q, "scale": scale}

    def unpack(self, planes, keep):
        return planes["packed"], planes["scale"]

    def analytic_tile_bytes(self, keep):
        return codec_api.tile_bytes(keep)

    def measured_tile_bits(self, q):
        k = q.shape[-1]
        return jnp.full(q.shape[:-2], 8 * codec_api.tile_bytes(k), jnp.int32)


# ---------------------------------------------------------------------------
# bitplane — EBPC-style zero-RLE accounting + bit-plane nonzero map
# ---------------------------------------------------------------------------

class BitplaneFamily(CodecFamily):
    name = "bitplane"
    # int8 coefficients, Eyeriss-style 5-bit saturated zero runs — the
    # arguments `core.encode.rle_codec_bits` is called with everywhere here.
    VALUE_BITS = 8
    RUN_BITS = 5

    @staticmethod
    def _mask_bytes(keep):
        return -(-(keep * keep) // 8)

    def plane_specs(self, keep, head_dim):
        nh = head_dim // BLOCK
        return (PlaneSpec("packed", jnp.int8, (nh, keep, keep)),
                PlaneSpec("bpmask", jnp.uint8, (nh, self._mask_bytes(keep))),
                PlaneSpec("blen", jnp.int32, (nh,)),
                PlaneSpec("scale", jnp.float32, (nh,)))

    def pack(self, q, scale, keep):
        kk = keep * keep
        mb = self._mask_bytes(keep)
        flat = q.reshape(q.shape[:-2] + (kk,))
        mask = flat != 0
        padded = jnp.pad(mask, [(0, 0)] * (mask.ndim - 1) + [(0, mb * 8 - kk)])
        bits = padded.reshape(padded.shape[:-1] + (mb, 8)).astype(jnp.uint8)
        bpmask = jnp.sum(bits << jnp.arange(8, dtype=jnp.uint8), axis=-1,
                         dtype=jnp.uint8)
        # front-pack the nonzeros: stable sort keeps their original order,
        # capacity stays the full kk so shapes are static under jit
        order = jnp.argsort(~mask, axis=-1, stable=True)
        payload = jnp.take_along_axis(flat, order, axis=-1)
        nnz = jnp.sum(mask, axis=-1, keepdims=True)
        payload = jnp.where(jnp.arange(kk) < nnz, payload, 0).astype(jnp.int8)
        blen = encode_lib.rle_codec_bits_tiles(flat, self.VALUE_BITS,
                                               self.RUN_BITS)
        return {"packed": payload.reshape(q.shape), "bpmask": bpmask,
                "blen": blen, "scale": scale}

    def unpack(self, planes, keep):
        kk = keep * keep
        mb = self._mask_bytes(keep)
        bpmask = planes["bpmask"]
        bits = (bpmask[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
        mask = bits.reshape(bpmask.shape[:-1] + (mb * 8,))[..., :kk] != 0
        payload = planes["packed"].reshape(mask.shape[:-1] + (kk,))
        rank = jnp.cumsum(mask, axis=-1) - 1
        vals = jnp.take_along_axis(payload, jnp.clip(rank, 0, kk - 1), axis=-1)
        flat = jnp.where(mask, vals, 0).astype(jnp.int8)
        return flat.reshape(mask.shape[:-1] + (keep, keep)), planes["scale"]

    def analytic_tile_bytes(self, keep):
        # worst case of the measured RLE stream (every coefficient non-zero:
        # k*k tokens of run_bits+value_bits) + the f32 scale header.  This
        # upper-bounds measured_tile_bits by construction; the static device
        # carrier (payload + bpmask + blen) is a separate, smaller
        # allocation accounted by the arrays themselves.
        kk = keep * keep
        return -(-(kk * (self.VALUE_BITS + self.RUN_BITS)) // 8) \
            + SCALE_HEADER_BYTES

    def measured_tile_bits(self, q):
        flat = q.reshape(q.shape[:-2] + (q.shape[-2] * q.shape[-1],))
        stream = encode_lib.rle_codec_bits_tiles(flat, self.VALUE_BITS,
                                                 self.RUN_BITS)
        return stream + 8 * SCALE_HEADER_BYTES


# ---------------------------------------------------------------------------
# asc — adaptive per-block scale exponent (1-byte header)
# ---------------------------------------------------------------------------

class AscFamily(CodecFamily):
    name = "asc"
    # scale' = 2 ** (sexp / 8): eighth-of-an-octave steps bound the relative
    # scale error below 2**(1/16) - 1 (~4.4%); -128 is the reserved
    # all-zero-tile code so empty blocks reconstruct exactly.
    EXP_DENOM = 8
    ZERO_CODE = -128

    def plane_specs(self, keep, head_dim):
        nh = head_dim // BLOCK
        return (PlaneSpec("packed", jnp.int8, (nh, keep, keep)),
                PlaneSpec("sexp", jnp.int8, (nh,)))

    def pack(self, q, scale, keep):
        e = jnp.round(jnp.log2(jnp.maximum(scale, 1e-30)) * self.EXP_DENOM)
        sexp = jnp.where(scale > 0, jnp.clip(e, -127, 127),
                         self.ZERO_CODE).astype(jnp.int8)
        return {"packed": q, "sexp": sexp}

    def unpack(self, planes, keep):
        sexp = planes["sexp"]
        scale = jnp.where(sexp == self.ZERO_CODE, 0.0,
                          jnp.exp2(sexp.astype(jnp.float32) / self.EXP_DENOM))
        return planes["packed"], scale

    def analytic_tile_bytes(self, keep):
        return keep * keep + 1  # int8 corner + 1-byte scale exponent

    def measured_tile_bits(self, q):
        k = q.shape[-1]
        return jnp.full(q.shape[:-2], 8 * (k * k + 1), jnp.int32)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_FAMILIES: dict[str, CodecFamily] = {}
_PLANE_NDIMS: dict[str, int] = {}

DEFAULT_FAMILY = "dct"
TAIL_NAMES = ("tail_k", "tail_v")  # raw per-slot scratchpad, outside families


def register_family(family: CodecFamily) -> None:
    """Register a family; plane names must keep a globally consistent block
    rank (the sharding rules dispatch on name + rank, so one plane name
    cannot mean two different layouts)."""
    assert family.name, "family needs a name"
    specs = family.plane_specs(BLOCK, BLOCK)
    if not any(s.name == "packed" for s in specs):
        raise ValueError(f"family {family.name!r} declares no 'packed' "
                         "carrier plane")
    for spec in specs:
        nd = len(spec.block_shape)
        if _PLANE_NDIMS.setdefault(spec.name, nd) != nd:
            raise ValueError(
                f"plane {spec.name!r} of family {family.name!r} has block "
                f"rank {nd}, but it is already registered with rank "
                f"{_PLANE_NDIMS[spec.name]}")
    _FAMILIES[family.name] = family


def get_family(name: str | None) -> CodecFamily:
    name = DEFAULT_FAMILY if name is None else name
    if name not in _FAMILIES:
        raise KeyError(
            f"unknown codec family {name!r}; have {available_families()}")
    return _FAMILIES[name]


def available_families() -> list[str]:
    return sorted(_FAMILIES)


def plane_block_ndims() -> dict[str, int]:
    """plane base name -> block rank, across every registered family — the
    table `parallel.sharding.cache_specs` dispatches cache planes on."""
    return dict(_PLANE_NDIMS)


register_family(DctFamily())
register_family(BitplaneFamily())
register_family(AscFamily())
