"""Fused Pallas `pallas` codec backend.

Wraps the raw kernels in `repro.kernels.*` with the one policy decision they
need — compile vs interpret — taken from `repro.codec.dispatch` instead of
being copy-pasted at every call site.  Layout conversion between the
kernels' plane-packed int8 output ``(R*k/8, C*k/8)`` and the repo-canonical
blocks layout ``(R/8, C/8, k, k)`` happens here, so consumers only ever see
one compressed representation regardless of backend.

This backend is the default on TPU (see dispatch.resolve_backend_name); on
CPU it runs the kernels in interpret mode, which the parity tests in
tests/test_codec_backends.py use to pin it against `reference`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.codec import dispatch
from repro.core import quantize as quant_lib
from repro.kernels.dct8x8 import kernel as dct_kernel
from repro.kernels.fused_compress import kernel as fc_kernel
from repro.kernels.quant_pack import kernel as qp_kernel

BLOCK = 8


class PallasBackend:
    name = "pallas"

    def __init__(self, interpret: bool | None = None):
        self._interpret = interpret  # None = auto (compiled on TPU only)

    @property
    def interpret(self) -> bool:
        return dispatch.resolve_interpret(self._interpret)

    def dct2_plane(self, x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
        return dct_kernel.dct2_plane_pallas(
            x, inverse=inverse, interpret=self.interpret
        )

    def compress_plane(self, x: jnp.ndarray, keep: int):
        packed, scale = fc_kernel.compress_plane_pallas(
            x, keep, interpret=self.interpret
        )
        nh, nw = scale.shape
        q = packed.reshape(nh, keep, nw, keep)
        return jnp.swapaxes(q, 1, 2), scale

    def decompress_plane(self, q: jnp.ndarray, scale: jnp.ndarray,
                         out_dtype=jnp.float32) -> jnp.ndarray:
        keep = q.shape[-1]
        nh, nw = scale.shape
        packed = jnp.swapaxes(q, 1, 2).reshape(nh * keep, nw * keep)
        return fc_kernel.decompress_plane_pallas(
            packed, scale, keep, out_dtype=out_dtype, interpret=self.interpret
        )

    def quant_pack_plane(self, x: jnp.ndarray, fmin, fmax, level: int,
                         bits: int = 8):
        qt_plane = quant_lib.qtable_plane(level, *x.shape)
        return qp_kernel.quant_pack_plane_pallas(
            x, fmin, fmax, qt_plane, bits=bits, interpret=self.interpret
        )
