"""Per-layer compression policy plans (paper §III-B, in API form).

The paper's accelerator programs a **2-bit compression-level register per
layer** and re-allocates the feature-map buffer to each layer's requirements.
This module is that mechanism as a first-class API: a frozen `LayerPolicy`
(keep/bits/enabled/backend) plus a `CompressionPlan` that resolves a policy
per layer index.  One plan object travels from config/CLI all the way to the
kernels — every consumer (ActCompress remat, the compressed KV cache, the
serve engine, the CNN fusion schedule) takes `plan=` instead of threading a
global scalar `compress_keep`.

Construction:

* presets          — ``CompressionPlan.uniform(keep=4)``,
                     ``CompressionPlan.pyramid(n_layers, 8, 3)``
                     (gentle-early / aggressive-late, ASC-style)
* spec strings     — ``CompressionPlan.from_spec("0-3:keep=6,4-:keep=3")``
                     for CLIs and configs; ``to_spec()`` is its inverse
* budget solver    — ``CompressionPlan.from_budget(cfg, max_seq, budget)``
                     picks the gentlest per-layer keeps whose summed KV
                     footprint fits the byte budget (the paper's dynamic
                     buffer allocation, solved off-line)

Plans and policies are frozen/hashable so they can ride as static jit
arguments and as pytree aux data.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace

BLOCK = 8
KEEP_MIN, KEEP_MAX = 1, BLOCK

# keep sizes of the paper's four quantization levels (core.quantize
# level_to_keep): aggressive level 0 -> 2x2 corner, gentle level 3 -> 6x6.
_KEEP_PER_LEVEL = (2, 3, 4, 6)


@dataclass(frozen=True)
class LayerPolicy:
    """Per-layer compression policy (the paper's per-layer level register).

    keep     — kept k x k low-frequency DCT corner (1..8; 8 = int8 quant only)
    bits     — step-1 integer precision of the paper-exact scheme
    enabled  — False => this layer is not compressed (ActCompress saves the
               raw residual; the CNN fusion boundary passes through)
    backend  — codec backend override for this layer (None = auto dispatch)
    codec    — codec FAMILY storing this layer's blocks (`codec.families`
               registry: dct / bitplane / asc); decides the plane tree the
               KV cache allocates and the per-tile byte accounting
    """

    keep: int = 4
    bits: int = 8
    enabled: bool = True
    backend: str | None = None
    codec: str = "dct"

    def __post_init__(self):
        if not KEEP_MIN <= self.keep <= KEEP_MAX:
            raise ValueError(f"keep must be in [{KEEP_MIN}, {KEEP_MAX}], got {self.keep}")
        if not 1 <= self.bits <= 16:
            raise ValueError(f"bits must be in [1, 16], got {self.bits}")
        from repro.codec import families as families_lib  # leaf-light import

        if self.codec not in families_lib.available_families():
            raise ValueError(
                f"unknown codec family {self.codec!r}; have "
                f"{families_lib.available_families()}")

    @property
    def kv_keep(self) -> int:
        """Corner size in the compressed KV store.

        The packed container has no raw mode, so a disabled layer keeps the
        full 8x8 corner — int8 quantization only, near-lossless."""
        return self.keep if self.enabled else KEEP_MAX

    @property
    def paper_level(self) -> int:
        """Nearest paper quantization level (2-bit register) for this keep."""
        level = 0
        for i, k in enumerate(_KEEP_PER_LEVEL):
            if self.keep >= k:
                level = i
        return level


# rules are (start, stop, policy) with stop=None meaning open-ended; first
# match wins, so narrower overrides go before broader ranges.
Rule = tuple[int, "int | None", LayerPolicy]


@dataclass(frozen=True)
class CompressionPlan:
    """Resolves a `LayerPolicy` per layer index — one policy object from
    config to kernel."""

    rules: tuple[Rule, ...] = ()
    default: LayerPolicy = LayerPolicy()

    # ------------------------------------------------------------ resolution
    def policy(self, idx: int) -> LayerPolicy:
        for start, stop, pol in self.rules:
            if idx >= start and (stop is None or idx < stop):
                return pol
        return self.default

    def policies(self, n_layers: int) -> tuple[LayerPolicy, ...]:
        return tuple(self.policy(i) for i in range(n_layers))

    def keeps(self, n_layers: int) -> tuple[int, ...]:
        return tuple(p.keep for p in self.policies(n_layers))

    def segments(self, n_layers: int, start: int = 0):
        """Contiguous (start, stop, policy) runs of equal policy covering
        [start, n_layers) — the scan-by-segment unit every stacked-layer
        consumer iterates over."""
        assert start < n_layers, (start, n_layers)
        out = []
        s0, pol = start, self.policy(start)
        for i in range(start + 1, n_layers):
            p = self.policy(i)
            if p != pol:
                out.append((s0, i, pol))
                s0, pol = i, p
        out.append((s0, n_layers, pol))
        return tuple(out)

    def is_uniform(self, n_layers: int) -> bool:
        return len(self.segments(n_layers)) == 1

    # ---------------------------------------------------------- constructors
    @classmethod
    def uniform(cls, keep: int = 4, bits: int = 8, backend: str | None = None,
                enabled: bool = True) -> "CompressionPlan":
        pol = LayerPolicy(keep=keep, bits=bits, enabled=enabled, backend=backend)
        return cls(rules=((0, None, pol),), default=pol)

    @classmethod
    def from_keeps(cls, keeps, bits: int = 8,
                   backend: str | None = None) -> "CompressionPlan":
        """Explicit per-layer keep list -> plan (runs collapsed to ranges)."""
        keeps = tuple(int(k) for k in keeps)
        assert keeps, "empty keep list"
        rules, s0 = [], 0
        for i in range(1, len(keeps)):
            if keeps[i] != keeps[s0]:
                rules.append((s0, i, LayerPolicy(keep=keeps[s0], bits=bits,
                                                 backend=backend)))
                s0 = i
        rules.append((s0, None, LayerPolicy(keep=keeps[s0], bits=bits,
                                            backend=backend)))
        return cls(rules=tuple(rules))

    @classmethod
    def pyramid(cls, n_layers: int, keep_first: int = 8, keep_last: int = 3,
                bits: int = 8, backend: str | None = None) -> "CompressionPlan":
        """Gentle-early / aggressive-late linear ramp (ASC-style): early
        layers' features feed everything downstream, so they get the larger
        kept corner."""
        if n_layers <= 1:
            return cls.uniform(keep_first, bits=bits, backend=backend)
        keeps = [round(keep_first + (keep_last - keep_first) * i / (n_layers - 1))
                 for i in range(n_layers)]
        return cls.from_keeps(keeps, bits=bits, backend=backend)

    # ----------------------------------------------------------- spec string
    # "0-3:keep=6,4-:codec=bitplane+keep=3" — comma-separated RANGE:SETTINGS
    # entries.  RANGE: "a" (one layer), "a-b" (inclusive), "a-" (open).
    # SETTINGS: "+"-separated keep=K / bits=B / backend=NAME / codec=FAMILY /
    # off flags.  Parse errors name the offending token and its character
    # position in the spec; unknown codec= names are rejected here, not at
    # trace time.
    _RANGE = re.compile(r"^(\d+)(-(\d*))?$")

    @classmethod
    def from_spec(cls, spec: str) -> "CompressionPlan":
        def fail(token: str, pos: int, why: str):
            raise ValueError(f"bad plan spec token {token!r} at position "
                             f"{pos} in {spec!r}: {why}")

        rules = []
        cursor = 0  # character offset of the current entry in `spec`
        for entry in spec.split(","):
            entry_pos = cursor + len(entry) - len(entry.lstrip())
            cursor += len(entry) + 1  # past the comma
            entry = entry.strip()
            if not entry:
                continue
            rng, sep, settings = entry.partition(":")
            m = cls._RANGE.match(rng.strip())
            if not m or not sep:
                fail(entry, entry_pos,
                     "want RANGE:SETTINGS, e.g. '0-3:keep=6'")
            start = int(m.group(1))
            if m.group(2) is None:
                stop: int | None = start + 1
            else:
                stop = int(m.group(3)) + 1 if m.group(3) else None
            if stop is not None and stop <= start:
                fail(rng.strip(), entry_pos, "empty layer range")
            kwargs: dict = {}
            item_cursor = entry_pos + len(rng) + 1  # past the colon
            for item in settings.split("+"):
                item_pos = item_cursor + len(item) - len(item.lstrip())
                item_cursor += len(item) + 1  # past the plus
                item = item.strip()
                if not item:
                    continue
                if item == "off":
                    kwargs["enabled"] = False
                elif item == "on":
                    kwargs["enabled"] = True
                else:
                    key, eq, val = item.partition("=")
                    if not eq:
                        fail(item, item_pos,
                             "want KEY=VALUE or the off/on flag")
                    key = key.strip()
                    val = val.strip()
                    if key == "keep":
                        kwargs["keep"] = int(val)
                    elif key == "bits":
                        kwargs["bits"] = int(val)
                    elif key == "backend":
                        kwargs["backend"] = val
                    elif key == "codec":
                        from repro.codec import families as families_lib

                        if val not in families_lib.available_families():
                            fail(item, item_pos,
                                 "unknown codec family; registered: "
                                 f"{families_lib.available_families()}")
                        kwargs["codec"] = val
                    else:
                        fail(item, item_pos, "unknown plan setting "
                             "(keep/bits/backend/codec/off/on)")
            rules.append((start, stop, LayerPolicy(**kwargs)))
        if not rules:
            raise ValueError(f"empty plan spec {spec!r}")
        return cls(rules=tuple(rules))

    def to_spec(self) -> str:
        """Inverse of `from_spec` (defaults omitted, roundtrip-exact)."""
        parts = []
        for start, stop, p in self.rules:
            if stop is None:
                rng = f"{start}-"
            elif stop == start + 1:
                rng = str(start)
            else:
                rng = f"{start}-{stop - 1}"
            settings = [f"keep={p.keep}"]
            if p.bits != 8:
                settings.append(f"bits={p.bits}")
            if p.backend is not None:
                settings.append(f"backend={p.backend}")
            if p.codec != "dct":
                settings.append(f"codec={p.codec}")
            if not p.enabled:
                settings.append("off")
            parts.append(f"{rng}:{'+'.join(settings)}")
        return ",".join(parts)

    # --------------------------------------------------------- budget solver
    @staticmethod
    def _layer_bytes_per_token(cfg, pol: LayerPolicy) -> float:
        """Analytic compressed KV bytes/token of ONE layer under `pol` —
        each policy's codec family charges its own worst-case tile bytes."""
        from repro.codec import families as families_lib

        hd = cfg.resolved_head_dim
        assert hd % BLOCK == 0, hd
        nh = hd // BLOCK
        fam = families_lib.get_family(pol.codec)
        return 2 * cfg.n_kv_heads * nh * fam.analytic_tile_bytes(pol.kv_keep) / BLOCK

    def kv_bytes_per_token(self, cfg) -> float:
        """Compressed KV bytes per token, summed over layers (K and V,
        headers included).  Derives from each policy's codec family
        `analytic_tile_bytes` — for the default dct family this is exactly
        `codec.api.tile_bytes`, the definition the codec's storage_stats
        and the KV pool report also charge."""
        return sum(self._layer_bytes_per_token(cfg, pol)
                   for pol in self.policies(cfg.n_layers))

    def page_bytes(self, cfg) -> int:
        """Bytes of one paged-pool page: one 8-token DCT block group across
        EVERY layer (all layers of a slot flush the same block index, so a
        page spans them all).  The allocation granule of the paged KV pool
        and the unit `ServeConfig.page_budget_mb` is solved in."""
        return int(round(self.kv_bytes_per_token(cfg) * BLOCK))

    def kv_cache_bytes(self, cfg, max_seq: int, batch: int = 1,
                       tail_dtype_bytes: int = 2) -> float:
        """Analytic bytes of the compressed KV pool this plan allocates:
        packed store for max_seq tokens plus the 8-token raw tail ring."""
        assert max_seq % BLOCK == 0, max_seq
        tail = cfg.n_layers * 2 * BLOCK * cfg.n_kv_heads * \
            cfg.resolved_head_dim * tail_dtype_bytes
        return batch * (self.kv_bytes_per_token(cfg) * max_seq + tail)

    @classmethod
    def from_budget(cls, cfg, max_seq: int, budget_bytes: float,
                    batch: int = 1, keep_max: int = KEEP_MAX,
                    keep_min: int = KEEP_MIN,
                    curves=None) -> "CompressionPlan":
        """Gentlest per-layer configuration whose summed KV footprint fits
        the budget.

        Without `curves`: greedy walk down a fixed chain of keep vectors —
        start every layer at `keep_max` and repeatedly decrement the largest
        keep (deepest layer first — aggressive-late, like `pyramid`).

        With `curves` (rows of ``{"codec", "keep", "ppl_delta"}`` as emitted
        into `benchmarks/plan_sweep.py`'s ``codec_curves`` artifact): a
        solver over (codec, keep) pairs.  The rows are reduced to their
        Pareto frontier (measured perplexity delta vs bytes), every layer
        starts at the best-quality point, and layers are walked down the
        frontier — most-expensive layer first, deepest on ties — until the
        budget fits.  A row may carry ``bytes_per_token`` (per-LAYER
        measured bytes/token, as plan_sweep records from the decoded
        cache); rows without it are charged their codec family's analytic
        worst case.  With measured rows the solver allocates by what tiles
        actually store — the ROADMAP's "allocate by measured, not
        analytic, size" — which is what lets variable-length families
        (bitplane) win frontier spots their analytic bound would lose.

        Either way the chain of configurations is independent of the budget,
        so a smaller budget stops strictly further along it and the solved
        plan is pointwise monotone in the budget.
        """
        if curves is not None:
            return cls._from_budget_curves(cfg, max_seq, budget_bytes,
                                           curves, batch=batch)
        keeps = [keep_max] * cfg.n_layers

        def fits(ks):
            return cls.from_keeps(ks).kv_cache_bytes(
                cfg, max_seq, batch=batch) <= budget_bytes

        while not fits(keeps):
            k = max(keeps)
            if k <= keep_min:
                need = cls.from_keeps(keeps).kv_cache_bytes(cfg, max_seq, batch=batch)
                raise ValueError(
                    f"budget {budget_bytes:.0f} B infeasible: even keep="
                    f"{keep_min} everywhere needs {need:.0f} B")
            idx = max(i for i, v in enumerate(keeps) if v == k)
            keeps[idx] = k - 1
        return cls.from_keeps(keeps)

    @classmethod
    def _from_budget_curves(cls, cfg, max_seq: int, budget_bytes: float,
                            curves, batch: int = 1) -> "CompressionPlan":
        points = []
        for row in curves:
            pol = LayerPolicy(keep=int(row["keep"]), codec=str(row["codec"]))
            bpt = float(row["bytes_per_token"]) if "bytes_per_token" in row \
                else cls._layer_bytes_per_token(cfg, pol)
            points.append((bpt, float(row["ppl_delta"]), pol))
        if not points:
            raise ValueError("from_budget: empty codec curves")
        # Pareto frontier: walking bytes ascending, keep a point only if it
        # improves on every cheaper point's perplexity.  The frontier is then
        # bytes-ascending / quality-improving; reverse so index 0 is the
        # best-quality (most expensive) configuration.
        points.sort(key=lambda e: (e[0], e[1]))
        frontier = []
        best = float("inf")
        for bpt, ppl, pol in points:
            if ppl < best - 1e-12:
                frontier.append((bpt, ppl, pol))
                best = ppl
        frontier.reverse()

        levels = [0] * cfg.n_layers  # per-layer index into the frontier

        def plan_of(lv):
            return cls.from_policies(frontier[j][2] for j in lv)

        # charge each layer the frontier row's OWN bytes/token (measured
        # when the row carries it), plus the raw bf16 tail ring — identical
        # to kv_cache_bytes when every row is analytic
        tail = cfg.n_layers * 2 * BLOCK * cfg.n_kv_heads * \
            cfg.resolved_head_dim * 2

        def bytes_of(lv):
            return batch * (sum(frontier[j][0] for j in lv) * max_seq + tail)

        def fits(lv):
            return bytes_of(lv) <= budget_bytes

        while not fits(levels):
            movable = [i for i in range(cfg.n_layers)
                       if levels[i] < len(frontier) - 1]
            if not movable:
                raise ValueError(
                    f"budget {budget_bytes:.0f} B infeasible: cheapest "
                    f"frontier point everywhere needs "
                    f"{bytes_of(levels):.0f} B")
            bmax = max(frontier[levels[i]][0] for i in movable)
            idx = max(i for i in movable if frontier[levels[i]][0] == bmax)
            levels[idx] += 1
        return plan_of(levels)

    # -------------------------------------------------------------- plumbing
    @classmethod
    def from_policies(cls, policies) -> "CompressionPlan":
        """Explicit per-layer policy sequence -> plan (runs collapsed)."""
        policies = tuple(policies)
        assert policies, "empty policy list"
        rules, s0 = [], 0
        for i in range(1, len(policies)):
            if policies[i] != policies[s0]:
                rules.append((s0, i, policies[s0]))
                s0 = i
        rules.append((s0, None, policies[s0]))
        return cls(rules=tuple(rules))

    def with_codec(self, codec: str | None) -> "CompressionPlan":
        """Set `codec` on EVERY policy (a global family override, unlike
        `with_backend`'s fill-if-unset — 'dct' is a real default, not an
        unset marker)."""
        if codec is None:
            return self
        return CompressionPlan(
            rules=tuple((s, e, replace(p, codec=codec))
                        for s, e, p in self.rules),
            default=replace(self.default, codec=codec),
        )

    def with_backend(self, backend: str | None) -> "CompressionPlan":
        """Fill in `backend` on every policy that does not set its own."""
        if backend is None:
            return self
        fill = lambda p: p if p.backend is not None else replace(p, backend=backend)
        return CompressionPlan(
            rules=tuple((s, e, fill(p)) for s, e, p in self.rules),
            default=fill(self.default),
        )


def raw_kv_bytes_per_token(cfg, dtype_bytes: int = 2) -> float:
    """Uncompressed (bf16 by default) KV bytes per token over all layers —
    the baseline every plan's `kv_bytes_per_token` is compared against."""
    return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.resolved_head_dim * dtype_bytes


def as_plan(value, *, keep: int | None = None, backend: str | None = None,
            codec: str | None = None) -> CompressionPlan:
    """Normalize any sanctioned plan spelling to a `CompressionPlan`.

    value: CompressionPlan (as-is) | spec string | int (uniform keep) |
    None (uniform `keep`, the legacy-scalar shim).  `backend` fills in
    policies that don't pin their own backend; `codec` (if given) overrides
    the codec family on every policy.
    """
    if value is None:
        plan = CompressionPlan.uniform(4 if keep is None else keep)
    elif isinstance(value, CompressionPlan):
        plan = value
    elif isinstance(value, str):
        plan = CompressionPlan.from_spec(value)
    elif isinstance(value, int):
        plan = CompressionPlan.uniform(value)
    else:
        raise TypeError(f"cannot interpret {value!r} as a CompressionPlan")
    return plan.with_backend(backend).with_codec(codec)
