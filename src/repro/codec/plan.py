"""Per-layer compression policy plans (paper §III-B, in API form).

The paper's accelerator programs a **2-bit compression-level register per
layer** and re-allocates the feature-map buffer to each layer's requirements.
This module is that mechanism as a first-class API: a frozen `LayerPolicy`
(keep/bits/enabled/backend) plus a `CompressionPlan` that resolves a policy
per layer index.  One plan object travels from config/CLI all the way to the
kernels — every consumer (ActCompress remat, the compressed KV cache, the
serve engine, the CNN fusion schedule) takes `plan=` instead of threading a
global scalar `compress_keep`.

Construction:

* presets          — ``CompressionPlan.uniform(keep=4)``,
                     ``CompressionPlan.pyramid(n_layers, 8, 3)``
                     (gentle-early / aggressive-late, ASC-style)
* spec strings     — ``CompressionPlan.from_spec("0-3:keep=6,4-:keep=3")``
                     for CLIs and configs; ``to_spec()`` is its inverse
* budget solver    — ``CompressionPlan.from_budget(cfg, max_seq, budget)``
                     picks the gentlest per-layer keeps whose summed KV
                     footprint fits the byte budget (the paper's dynamic
                     buffer allocation, solved off-line)

Plans and policies are frozen/hashable so they can ride as static jit
arguments and as pytree aux data.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace

BLOCK = 8
KEEP_MIN, KEEP_MAX = 1, BLOCK

# keep sizes of the paper's four quantization levels (core.quantize
# level_to_keep): aggressive level 0 -> 2x2 corner, gentle level 3 -> 6x6.
_KEEP_PER_LEVEL = (2, 3, 4, 6)


@dataclass(frozen=True)
class LayerPolicy:
    """Per-layer compression policy (the paper's per-layer level register).

    keep     — kept k x k low-frequency DCT corner (1..8; 8 = int8 quant only)
    bits     — step-1 integer precision of the paper-exact scheme
    enabled  — False => this layer is not compressed (ActCompress saves the
               raw residual; the CNN fusion boundary passes through)
    backend  — codec backend override for this layer (None = auto dispatch)
    """

    keep: int = 4
    bits: int = 8
    enabled: bool = True
    backend: str | None = None

    def __post_init__(self):
        if not KEEP_MIN <= self.keep <= KEEP_MAX:
            raise ValueError(f"keep must be in [{KEEP_MIN}, {KEEP_MAX}], got {self.keep}")
        if not 1 <= self.bits <= 16:
            raise ValueError(f"bits must be in [1, 16], got {self.bits}")

    @property
    def kv_keep(self) -> int:
        """Corner size in the compressed KV store.

        The packed container has no raw mode, so a disabled layer keeps the
        full 8x8 corner — int8 quantization only, near-lossless."""
        return self.keep if self.enabled else KEEP_MAX

    @property
    def paper_level(self) -> int:
        """Nearest paper quantization level (2-bit register) for this keep."""
        level = 0
        for i, k in enumerate(_KEEP_PER_LEVEL):
            if self.keep >= k:
                level = i
        return level


# rules are (start, stop, policy) with stop=None meaning open-ended; first
# match wins, so narrower overrides go before broader ranges.
Rule = tuple[int, "int | None", LayerPolicy]


@dataclass(frozen=True)
class CompressionPlan:
    """Resolves a `LayerPolicy` per layer index — one policy object from
    config to kernel."""

    rules: tuple[Rule, ...] = ()
    default: LayerPolicy = LayerPolicy()

    # ------------------------------------------------------------ resolution
    def policy(self, idx: int) -> LayerPolicy:
        for start, stop, pol in self.rules:
            if idx >= start and (stop is None or idx < stop):
                return pol
        return self.default

    def policies(self, n_layers: int) -> tuple[LayerPolicy, ...]:
        return tuple(self.policy(i) for i in range(n_layers))

    def keeps(self, n_layers: int) -> tuple[int, ...]:
        return tuple(p.keep for p in self.policies(n_layers))

    def segments(self, n_layers: int, start: int = 0):
        """Contiguous (start, stop, policy) runs of equal policy covering
        [start, n_layers) — the scan-by-segment unit every stacked-layer
        consumer iterates over."""
        assert start < n_layers, (start, n_layers)
        out = []
        s0, pol = start, self.policy(start)
        for i in range(start + 1, n_layers):
            p = self.policy(i)
            if p != pol:
                out.append((s0, i, pol))
                s0, pol = i, p
        out.append((s0, n_layers, pol))
        return tuple(out)

    def is_uniform(self, n_layers: int) -> bool:
        return len(self.segments(n_layers)) == 1

    # ---------------------------------------------------------- constructors
    @classmethod
    def uniform(cls, keep: int = 4, bits: int = 8, backend: str | None = None,
                enabled: bool = True) -> "CompressionPlan":
        pol = LayerPolicy(keep=keep, bits=bits, enabled=enabled, backend=backend)
        return cls(rules=((0, None, pol),), default=pol)

    @classmethod
    def from_keeps(cls, keeps, bits: int = 8,
                   backend: str | None = None) -> "CompressionPlan":
        """Explicit per-layer keep list -> plan (runs collapsed to ranges)."""
        keeps = tuple(int(k) for k in keeps)
        assert keeps, "empty keep list"
        rules, s0 = [], 0
        for i in range(1, len(keeps)):
            if keeps[i] != keeps[s0]:
                rules.append((s0, i, LayerPolicy(keep=keeps[s0], bits=bits,
                                                 backend=backend)))
                s0 = i
        rules.append((s0, None, LayerPolicy(keep=keeps[s0], bits=bits,
                                            backend=backend)))
        return cls(rules=tuple(rules))

    @classmethod
    def pyramid(cls, n_layers: int, keep_first: int = 8, keep_last: int = 3,
                bits: int = 8, backend: str | None = None) -> "CompressionPlan":
        """Gentle-early / aggressive-late linear ramp (ASC-style): early
        layers' features feed everything downstream, so they get the larger
        kept corner."""
        if n_layers <= 1:
            return cls.uniform(keep_first, bits=bits, backend=backend)
        keeps = [round(keep_first + (keep_last - keep_first) * i / (n_layers - 1))
                 for i in range(n_layers)]
        return cls.from_keeps(keeps, bits=bits, backend=backend)

    # ----------------------------------------------------------- spec string
    # "0-3:keep=6,4-:keep=3" — comma-separated RANGE:SETTINGS entries.
    # RANGE: "a" (one layer), "a-b" (inclusive), "a-" (open). SETTINGS:
    # "+"-separated keep=K / bits=B / backend=NAME / off flags.
    _RANGE = re.compile(r"^(\d+)(-(\d*))?$")

    @classmethod
    def from_spec(cls, spec: str) -> "CompressionPlan":
        rules = []
        for entry in spec.split(","):
            entry = entry.strip()
            if not entry:
                continue
            rng, sep, settings = entry.partition(":")
            m = cls._RANGE.match(rng.strip())
            if not m or not sep:
                raise ValueError(f"bad plan spec entry {entry!r} "
                                 "(want RANGE:SETTINGS, e.g. '0-3:keep=6')")
            start = int(m.group(1))
            if m.group(2) is None:
                stop: int | None = start + 1
            else:
                stop = int(m.group(3)) + 1 if m.group(3) else None
            if stop is not None and stop <= start:
                raise ValueError(f"empty range in plan spec entry {entry!r}")
            kwargs: dict = {}
            for item in settings.split("+"):
                item = item.strip()
                if not item:
                    continue
                if item == "off":
                    kwargs["enabled"] = False
                elif item == "on":
                    kwargs["enabled"] = True
                else:
                    key, eq, val = item.partition("=")
                    if not eq:
                        raise ValueError(f"bad plan setting {item!r} in {entry!r}")
                    key = key.strip()
                    val = val.strip()
                    if key == "keep":
                        kwargs["keep"] = int(val)
                    elif key == "bits":
                        kwargs["bits"] = int(val)
                    elif key == "backend":
                        kwargs["backend"] = val
                    else:
                        raise ValueError(f"unknown plan setting {key!r} in {entry!r}")
            rules.append((start, stop, LayerPolicy(**kwargs)))
        if not rules:
            raise ValueError(f"empty plan spec {spec!r}")
        return cls(rules=tuple(rules))

    def to_spec(self) -> str:
        """Inverse of `from_spec` (defaults omitted, roundtrip-exact)."""
        parts = []
        for start, stop, p in self.rules:
            if stop is None:
                rng = f"{start}-"
            elif stop == start + 1:
                rng = str(start)
            else:
                rng = f"{start}-{stop - 1}"
            settings = [f"keep={p.keep}"]
            if p.bits != 8:
                settings.append(f"bits={p.bits}")
            if p.backend is not None:
                settings.append(f"backend={p.backend}")
            if not p.enabled:
                settings.append("off")
            parts.append(f"{rng}:{'+'.join(settings)}")
        return ",".join(parts)

    # --------------------------------------------------------- budget solver
    def kv_bytes_per_token(self, cfg) -> float:
        """Compressed KV bytes per token, summed over layers (K and V:
        int8 packed corner + the f32 per-tile scale header).  Derives from
        `codec.api.tile_bytes` — the one per-tile definition the codec's
        storage_stats and the KV pool report also charge."""
        from repro.codec.api import tile_bytes  # local: plan stays leaf-light

        hd = cfg.resolved_head_dim
        assert hd % BLOCK == 0, hd
        nh = hd // BLOCK
        return sum(
            2 * cfg.n_kv_heads * nh * tile_bytes(pol.kv_keep) / BLOCK
            for pol in self.policies(cfg.n_layers))

    def page_bytes(self, cfg) -> int:
        """Bytes of one paged-pool page: one 8-token DCT block group across
        EVERY layer (all layers of a slot flush the same block index, so a
        page spans them all).  The allocation granule of the paged KV pool
        and the unit `ServeConfig.page_budget_mb` is solved in."""
        return int(round(self.kv_bytes_per_token(cfg) * BLOCK))

    def kv_cache_bytes(self, cfg, max_seq: int, batch: int = 1,
                       tail_dtype_bytes: int = 2) -> float:
        """Analytic bytes of the compressed KV pool this plan allocates:
        packed store for max_seq tokens plus the 8-token raw tail ring."""
        assert max_seq % BLOCK == 0, max_seq
        tail = cfg.n_layers * 2 * BLOCK * cfg.n_kv_heads * \
            cfg.resolved_head_dim * tail_dtype_bytes
        return batch * (self.kv_bytes_per_token(cfg) * max_seq + tail)

    @classmethod
    def from_budget(cls, cfg, max_seq: int, budget_bytes: float,
                    batch: int = 1, keep_max: int = KEEP_MAX,
                    keep_min: int = KEEP_MIN) -> "CompressionPlan":
        """Gentlest per-layer keeps whose summed KV footprint fits the budget.

        Greedy walk down a fixed chain of configurations: start every layer
        at `keep_max` and repeatedly decrement the largest keep (deepest
        layer first — aggressive-late, like `pyramid`).  Because the chain is
        independent of the budget, a smaller budget stops strictly further
        along it, so keeps are pointwise monotone in the budget.
        """
        keeps = [keep_max] * cfg.n_layers

        def fits(ks):
            return cls.from_keeps(ks).kv_cache_bytes(
                cfg, max_seq, batch=batch) <= budget_bytes

        while not fits(keeps):
            k = max(keeps)
            if k <= keep_min:
                need = cls.from_keeps(keeps).kv_cache_bytes(cfg, max_seq, batch=batch)
                raise ValueError(
                    f"budget {budget_bytes:.0f} B infeasible: even keep="
                    f"{keep_min} everywhere needs {need:.0f} B")
            idx = max(i for i, v in enumerate(keeps) if v == k)
            keeps[idx] = k - 1
        return cls.from_keeps(keeps)

    # -------------------------------------------------------------- plumbing
    def with_backend(self, backend: str | None) -> "CompressionPlan":
        """Fill in `backend` on every policy that does not set its own."""
        if backend is None:
            return self
        fill = lambda p: p if p.backend is not None else replace(p, backend=backend)
        return CompressionPlan(
            rules=tuple((s, e, fill(p)) for s, e, p in self.rules),
            default=fill(self.default),
        )


def raw_kv_bytes_per_token(cfg, dtype_bytes: int = 2) -> float:
    """Uncompressed (bf16 by default) KV bytes per token over all layers —
    the baseline every plan's `kv_bytes_per_token` is compared against."""
    return cfg.n_layers * 2 * cfg.n_kv_heads * cfg.resolved_head_dim * dtype_bytes


def as_plan(value, *, keep: int | None = None,
            backend: str | None = None) -> CompressionPlan:
    """Normalize any sanctioned plan spelling to a `CompressionPlan`.

    value: CompressionPlan (as-is) | spec string | int (uniform keep) |
    None (uniform `keep`, the legacy-scalar shim).  `backend` fills in
    policies that don't pin their own backend.
    """
    if value is None:
        plan = CompressionPlan.uniform(4 if keep is None else keep)
    elif isinstance(value, CompressionPlan):
        plan = value
    elif isinstance(value, str):
        plan = CompressionPlan.from_spec(value)
    elif isinstance(value, int):
        plan = CompressionPlan.uniform(value)
    else:
        raise TypeError(f"cannot interpret {value!r} as a CompressionPlan")
    return plan.with_backend(backend)
