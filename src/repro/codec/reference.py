"""Pure-JAX `reference` codec backend.

Implements the backend plane protocol with einsum 8x8 transforms — runs on
any JAX backend, differentiates (the Pallas kernels do not define VJPs), and
serves as the numerical oracle the `pallas` backend is tested against.

Plane protocol (all planes are 2-D with R % 8 == 0 and C % 8 == 0; leading
dims are folded away by `repro.codec.api` before dispatch):

  dct2_plane(x, inverse)            -> (R, C) blocked 8x8 DCT/IDCT
  compress_plane(x, keep)           -> (q (R/8, C/8, k, k) int8,
                                        scale (R/8, C/8) f32)
  decompress_plane(q, scale, dtype) -> (R, C)
  quant_pack_plane(x, fmin, fmax, level, bits)
                                    -> (q2 i32, index i8, nnz i32)  [Eq. 7-8]
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import dct as dct_lib
from repro.core import quantize as quant_lib

BLOCK = 8


def _dct_rows(keep: int) -> jnp.ndarray:
    """(keep, 8) top rows of the orthonormal DCT matrix — fused DCT+truncate."""
    return jnp.asarray(dct_lib._dct_matrix_np(BLOCK)[:keep], jnp.float32)


class ReferenceBackend:
    name = "reference"

    def dct2_plane(self, x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
        blocks = dct_lib._blockize(x)
        f = dct_lib.idct2_blocks if inverse else dct_lib.dct2_blocks
        return dct_lib._unblockize(f(blocks, jnp.float32)).astype(x.dtype)

    def compress_plane(self, x: jnp.ndarray, keep: int):
        ck = _dct_rows(keep)
        blocks = dct_lib._blockize(x.astype(jnp.float32))
        z = jnp.einsum("ua,...ab,vb->...uv", ck, blocks, ck)  # DCT + truncate
        amax = jnp.max(jnp.abs(z), axis=(-1, -2), keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(z / scale), -127, 127).astype(jnp.int8)
        return q, scale[..., 0, 0]

    def decompress_plane(self, q: jnp.ndarray, scale: jnp.ndarray,
                         out_dtype=jnp.float32) -> jnp.ndarray:
        ck = _dct_rows(q.shape[-1])
        z = q.astype(jnp.float32) * scale[..., None, None]
        t = jnp.einsum("ua,...uv,vb->...ab", ck, z, ck)  # zero-pad + IDCT
        return dct_lib._unblockize(t).astype(out_dtype)

    def quant_pack_plane(self, x: jnp.ndarray, fmin, fmax, level: int,
                         bits: int = 8):
        params = quant_lib.QuantParams(
            jnp.asarray(fmin, jnp.float32), jnp.asarray(fmax, jnp.float32), bits
        )
        q1 = quant_lib.quantize_minmax(x.astype(jnp.float32), params)
        qt = quant_lib.qtable_plane(level, *x.shape)
        q2 = jnp.round((q1 - params.zero_point) / qt)
        index = (q2 != 0).astype(jnp.int8)
        return q2.astype(jnp.int32), index, jnp.sum(index.astype(jnp.int32))
