"""Architecture config system: one dataclass, ten public-literature configs.

Every assigned architecture is a `src/repro/configs/<id>.py` exporting CONFIG;
`registry()` resolves `--arch <id>`.  `reduced()` scales any config down to a
CPU-smoke-test size of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

ARCH_IDS = [
    "deepseek_v2_236b",
    "moonshot_v1_16b_a3b",
    "nemotron_4_340b",
    "yi_6b",
    "qwen2_0_5b",
    "command_r_plus_104b",
    "llava_next_mistral_7b",
    "whisper_base",
    "zamba2_2_7b",
    "rwkv6_1_6b",
]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    # attention
    attn_type: str = "gqa"            # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MLP
    mlp_type: str = "gated_silu"      # gated_silu | squared_relu | gelu
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0            # leading dense layers before MoE layers
    moe_capacity_factor: float = 2.0  # expert queue = group*topk/E * cf
    moe_dropless: bool = False        # capacity = group size (no drops)
    moe_group_size: int = 1024        # dispatch group (bounds the one-hot)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0               # hybrid: shared attn block every N blocks
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0          # fixed encoder context (audio frames)
    # modality frontend stub
    frontend: str = "none"            # none | vision_stub | audio_stub
    frontend_tokens: int = 0          # precomputed embedding tokens prepended
    # misc
    tie_embeddings: bool = False
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    supports_long_context: bool = False  # sub-quadratic sequence mixing
    max_seq_len: int = 0              # architectural cap (0 = unbounded)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vec_pos_decode(self) -> bool:
        """Decode takes a per-slot (B,) position vector (continuous batching).

        True for the transformer families whose cache is indexed by absolute
        position; recurrent/hybrid families advance a state with one scalar
        step index and are served lock-step. Single source of truth for
        serve/engine.make_steps and ModelAPI.input_specs.
        """
        return self.family in ("dense", "moe", "vlm")

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ----- shape applicability (DESIGN.md §4) ---------------------------
    def shape_supported(self, shape_name: str) -> tuple[bool, str]:
        seq, _, kind = SHAPES[shape_name]
        if shape_name == "long_500k" and not self.supports_long_context:
            return False, "full-attention arch: 512k dense decode is quadratic-cost (skip per assignment)"
        if kind == "decode" and self.max_seq_len and seq > self.max_seq_len:
            # whisper: a 32k-token KV decode is outside the 448-token decoder
            # envelope. (prefill/train shapes are reinterpreted instead:
            # enc 1500 frames + dec <= cap, see ModelAPI.shape_plan.)
            return False, f"architectural context cap {self.max_seq_len} < {seq}"
        return True, ""

    # ----- smoke-test reduction -----------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family/code paths, CPU-sized."""
        r = {
            "name": self.name + "_reduced",
            "n_layers": min(self.n_layers, 4 if self.attn_every == 0 else 2 * max(self.attn_every, 1)),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            "head_dim": 16,
            "d_ff": 128,
            "vocab_size": 256,
            "encoder_seq_len": min(self.encoder_seq_len, 32) if self.encoder_seq_len else 0,
            "frontend_tokens": min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            "max_seq_len": 0,
        }
        if self.n_experts:
            r.update(n_experts=8, top_k=2, moe_d_ff=32,
                     n_shared_experts=min(self.n_shared_experts, 1),
                     first_k_dense=min(self.first_k_dense, 1))
        if self.attn_type == "mla":
            r.update(kv_lora_rank=32, q_lora_rank=32,
                     qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm_state:
            r.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.attn_every:
            r.update(attn_every=2)
        if self.n_encoder_layers:
            r.update(n_encoder_layers=2)
        return replace(self, **r)

    # ----- parameter count (for roofline MODEL_FLOPS) --------------------
    def param_counts(self) -> dict[str, float]:
        """Analytic total and active parameter counts (embedding included)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = 0.0
        if self.attn_type == "gqa":
            per_layer_attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        elif self.attn_type == "mla":
            r, qr = self.kv_lora_rank, self.q_lora_rank
            nope, rope, vh = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            per_layer_attn = d * (r + rope) + r * self.n_heads * (nope + vh) + self.n_heads * vh * d
            per_layer_attn += (d * qr + qr * self.n_heads * (nope + rope)) if qr else d * self.n_heads * (nope + rope)
        dense_mlp = d * self.d_ff * (3 if self.mlp_type == "gated_silu" else 2)
        total = embed
        active = embed
        if self.ssm_state and self.attn_every == 0:
            pass  # pure ssm handled by family below
        if self.family in ("dense", "vlm", "audio"):
            total += L * (per_layer_attn + dense_mlp)
            active = total
            if self.is_encoder_decoder:
                # encoder layers + cross attention in decoder
                total += self.n_encoder_layers * (per_layer_attn + dense_mlp)
                total += L * per_layer_attn  # cross-attn
                active = total
        elif self.family == "moe":
            moe_mlp = 3 * d * self.moe_d_ff
            shared = 3 * d * self.moe_d_ff * self.n_shared_experts
            router = d * self.n_experts
            n_moe = L - self.first_k_dense
            total += L * per_layer_attn + self.first_k_dense * dense_mlp
            total += n_moe * (self.n_experts * moe_mlp + shared + router)
            active = embed + L * per_layer_attn + self.first_k_dense * dense_mlp
            active += n_moe * (self.top_k * moe_mlp + shared + router)
        elif self.family == "hybrid":
            d_inner = self.ssm_expand * d
            ssm_block = d * d_inner * 2 + d_inner * self.ssm_state * 2 + d_inner * d  # in/gate, B/C, out
            n_attn = L // max(self.attn_every, 1)
            total += L * ssm_block + (per_layer_attn + dense_mlp)  # shared attn counted once
            active = embed + L * ssm_block + n_attn * (per_layer_attn + dense_mlp)
        elif self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,o ~ 5 d^2) + channel-mix (2 d*dff)
            per = 5 * d * d + 2 * d * self.d_ff
            total += L * per
            active = total
        return {"total": float(total), "active": float(active)}


def registry() -> dict[str, ArchConfig]:
    out = {}
    for arch_id in ARCH_IDS:
        mod = importlib.import_module(f"repro.configs.{arch_id}")
        out[arch_id] = mod.CONFIG
    return out


def get_config(arch_id: str) -> ArchConfig:
    return registry()[arch_id.replace("-", "_")]
