"""Command R+ 104B (dense GQA, no bias) [hf:CohereForAI/c4ai-command-r-plus]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command_r_plus_104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    attn_type="gqa",
    mlp_type="gated_silu",
    rope_theta=75e6,
    source="hf:CohereForAI/c4ai-command-r-plus",
)
