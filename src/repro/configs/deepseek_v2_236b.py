"""DeepSeek-V2 236B (MoE, MLA) [arXiv:2405.04434; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_v2_236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: kv heads == heads, latent-cached
    head_dim=128,
    d_ff=12288,              # dense layers' FFN (first_k_dense)
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mlp_type="gated_silu",
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_k_dense=1,
    rope_theta=1e4,
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)
