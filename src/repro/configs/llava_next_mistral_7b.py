"""LLaVA-NeXT (Mistral-7B backbone, anyres vision stub) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The modality frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (anyres tiling yields up to 2880 patch tokens),
prepended to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava_next_mistral_7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attn_type="gqa",
    mlp_type="gated_silu",
    frontend="vision_stub",
    frontend_tokens=2880,
    rope_theta=1e6,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
