"""Moonlight-16B-A3B (MoE) [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot_v1_16b_a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11264,              # dense first layer FFN
    vocab_size=163840,
    attn_type="gqa",
    mlp_type="gated_silu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    rope_theta=5e4,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
