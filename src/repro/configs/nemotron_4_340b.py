"""Nemotron-4 340B (dense, squared-ReLU) [arXiv:2402.16819]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron_4_340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    attn_type="gqa",
    mlp_type="squared_relu",
    rope_theta=1e4,
    source="arXiv:2402.16819",
)
