"""RWKV-6 Finch 1.6B (attention-free, data-dependent decay) [arXiv:2404.05892]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_1_6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,              # wkv heads = d_model / 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attn_type="none",
    mlp_type="gelu",
    ssm_chunk=16,  # intra-chunk decay factoring bound: exp(|LOG_W_MIN|*chunk) must fit f32
    supports_long_context=True,
    source="arXiv:2404.05892",
)
