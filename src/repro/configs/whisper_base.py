"""Whisper-base (enc-dec, conv frontend stub) [arXiv:2212.04356].

Encoder consumes precomputed frame embeddings (1500 frames = 30 s audio,
conv frontend stubbed per assignment).  Decoder context cap is 448 tokens
(architectural), so decode_32k / long_500k shapes are skipped (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    attn_type="gqa",
    mlp_type="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq_len=1500,
    frontend="audio_stub",
    max_seq_len=448,
    source="arXiv:2212.04356",
)
