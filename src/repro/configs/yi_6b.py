"""Yi-6B (llama-arch GQA) [arXiv:2403.04652; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi_6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    attn_type="gqa",
    mlp_type="gated_silu",
    rope_theta=5e6,
    source="arXiv:2403.04652; hf:01-ai/Yi-6B",
)
