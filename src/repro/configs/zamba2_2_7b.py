"""Zamba2-2.7B (Mamba2 blocks + shared attention) [arXiv:2411.15242; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    attn_type="gqa",
    mlp_type="gelu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
    supports_long_context=True,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)
