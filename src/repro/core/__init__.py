"""Paper core: interlayer feature-map compression (DCT + quant + sparse code)."""
from repro.core.compressor import (
    Compressed,
    CompressionPolicy,
    TruncatedCompressed,
    compress,
    compress_truncated,
    compression_ratio,
    decompress,
    decompress_truncated,
    roundtrip,
    roundtrip_truncated,
)

__all__ = [
    "Compressed",
    "CompressionPolicy",
    "TruncatedCompressed",
    "compress",
    "compress_truncated",
    "compression_ratio",
    "decompress",
    "decompress_truncated",
    "roundtrip",
    "roundtrip_truncated",
]
