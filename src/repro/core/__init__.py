"""Paper core: interlayer feature-map compression (DCT + quant + sparse code).

Submodules import lazily (PEP 562) so that `repro.codec` — which the
compressor facade delegates to — can import `repro.core.dct` /
`repro.core.quantize` without triggering the facade and creating an import
cycle.  `from repro.core import compressor` and `repro.core.Compressed`
both keep working.
"""
__all__ = [
    "Compressed",
    "CompressionPolicy",
    "TruncatedCompressed",
    "compress",
    "compress_truncated",
    "compression_ratio",
    "decompress",
    "decompress_truncated",
    "roundtrip",
    "roundtrip_truncated",
]


def __getattr__(name):
    if name in __all__:
        from repro.core import compressor

        return getattr(compressor, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
