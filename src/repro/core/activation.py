"""ActCompress: DCT-compressed activation checkpointing (DESIGN.md §3.1).

The paper stores interlayer feature maps compressed so the expensive memory
level never holds raw activations.  In training, the analogous expensive
storage is the saved-for-backward residual stream: with per-layer remat the
residual input of every layer is pinned in HBM for the whole backward.

`compressed_checkpoint(body, keep)` wraps a layer body so its input residual
is saved as DCT-truncated int8 (k*k/64 * 1B of the 2B bf16 element => e.g.
keep=4 stores 0.19 B/elem, a 10.7x reduction) and decompressed on the fly in
the backward pass, where the layer is recomputed from the reconstruction.

Gradient bias: identical in kind to activation-compressed training (ActNN,
GACT); the compression error enters only through the recomputation point.
benchmarks/accuracy_loss.py measures the end-to-end effect.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro import codec as codec_lib


def _compressible(x: jax.Array) -> bool:
    if x.ndim < 2:
        return False
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return rows % 8 == 0 and x.shape[-1] % 8 == 0


@jax.tree_util.register_pytree_node_class
@dataclass
class SavedAct:
    """custom_vjp residual carrier: payload is a pytree child, the original
    shape/dtype ride as STATIC aux data (dtype objects are not JAX types)."""

    payload: Any              # TruncatedCompressed | raw array
    shape: tuple              # static
    dtype_name: str           # static
    compressed: bool          # static

    def tree_flatten(self):
        return (self.payload,), (self.shape, self.dtype_name, self.compressed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def compress_activation(x: jax.Array, keep: int, backend: str | None = None):
    """(..., D) -> TruncatedCompressed of the flattened (rows, D) plane."""
    plane = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return codec_lib.Codec(keep=keep, backend=backend).compress(plane)


def decompress_activation(c, shape, dtype, backend: str | None = None):
    plane = codec_lib.Codec(keep=c.keep, backend=backend).decompress(c, jnp.float32)
    return plane.reshape(shape).astype(dtype)


def compressed_checkpoint(body, keep: int | None = 4, grad_dtype=None,
                          backend: str | None = None):
    """jax.checkpoint analogue whose saved residual is DCT-compressed.

    body: (params_pytree, x) -> y with y.shape == x.shape (residual layer).
    The wrapper must not close over tracers — compute positions etc. inside
    `body` from `x` itself.

    keep=None saves the raw residual (plain remat semantics) — used when only
    the grad_dtype boundary is wanted.

    grad_dtype (e.g. bf16): cast the PARAM cotangents inside the backward,
    i.e. before XLA's per-layer cross-DP reduction — this is the only place
    a wire-dtype choice can reach the in-loop gradient all-reduce (a cast on
    the stacked grads after the scan is downstream of the collectives).

    backend: codec backend override (None = auto per repro.codec.dispatch).
    The backward never differentiates *through* the codec — the compression
    error enters only via the recomputation point — so the fused Pallas
    backend is safe here.
    """

    @jax.custom_vjp
    def wrapped(p, x):
        return body(p, x)

    def fwd(p, x):
        y = body(p, x)
        if keep is not None and _compressible(x):
            saved = SavedAct(compress_activation(x, keep, backend), x.shape, x.dtype.name, True)
        else:  # raw remat residual (keep=None or shape not 8-alignable)
            saved = SavedAct(x, x.shape, x.dtype.name, False)
        return y, (p, saved)

    def bwd(res, g):
        p, saved = res
        if saved.compressed:
            x_hat = decompress_activation(
                saved.payload, saved.shape, jnp.dtype(saved.dtype_name), backend
            )
        else:
            x_hat = saved.payload
        _, vjp = jax.vjp(body, p, x_hat)
        gp, gx = vjp(g)
        if grad_dtype is not None:
            gp = jax.tree.map(lambda t: t.astype(grad_dtype), gp)
        return gp, gx

    wrapped.defvjp(fwd, bwd)
    return wrapped
