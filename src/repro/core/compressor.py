"""End-to-end interlayer feature-map codec (paper §III, Fig. 3/4).

Two paths:

* `compress` / `decompress` — the paper-exact pipeline:
      DCT -> min-max m-bit quant -> Q-table quant -> bitmap encode
  and its inverse.  Fixed-shape JAX throughout (the sparse *accounting* lives
  in encode.py); used by the CNN repro and the compression-ratio benchmarks.

* `compress_truncated` / `decompress_truncated` — the TPU runtime path
  (DESIGN.md §2): DCT -> min-max int8 -> keep only the k x k low-frequency
  corner, stored dense.  Fixed shapes, MXU-aligned, usable inside jit/remat/
  custom_vjp with zero host round-trips.  This is what ActCompress/KVCompress
  use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dct as dct_lib
from repro.core import encode as encode_lib
from repro.core import quantize as quant_lib

BLOCK = 8


@dataclass(frozen=True)
class CompressionPolicy:
    """Per-layer policy (paper: 2-bit level register + compressed-layer set)."""

    level: int = 1          # 0 aggressive ... 3 gentle (paper's 4 levels)
    bits: int = 8           # step-1 integer precision m
    enabled: bool = True

    def keep(self) -> int:
        return quant_lib.level_to_keep(self.level)


@jax.tree_util.register_pytree_node_class
@dataclass
class Compressed:
    """Paper-exact compressed representation of a (..., H, W) tensor."""

    values: jax.Array      # (..., nh, nw, 8, 8) quantized coefficients (int32)
    index: jax.Array       # same shape, bool
    fmin: jax.Array
    fmax: jax.Array
    level: int
    bits: int
    orig_hw: tuple[int, int]

    def tree_flatten(self):
        return (self.values, self.index, self.fmin, self.fmax), (
            self.level,
            self.bits,
            self.orig_hw,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, index, fmin, fmax = children
        level, bits, orig_hw = aux
        return cls(values, index, fmin, fmax, level, bits, orig_hw)


def compress(x: jax.Array, policy: CompressionPolicy) -> Compressed:
    """Paper pipeline: pad -> blockize -> DCT -> quant x2 -> bitmap encode."""
    *_, h, w = x.shape
    padded, _ = dct_lib.pad_to_block(x)
    blocks = dct_lib._blockize(padded)
    coefs = dct_lib.dct2_blocks(blocks)
    q2, params = quant_lib.quantize_blocks(coefs, policy.level, policy.bits)
    enc = encode_lib.encode_blocks(q2)
    return Compressed(
        values=enc.values,
        index=enc.index,
        fmin=params.fmin,
        fmax=params.fmax,
        level=policy.level,
        bits=policy.bits,
        orig_hw=(h, w),
    )


def decompress(c: Compressed, dtype=jnp.float32) -> jax.Array:
    """Inverse: decode -> inverse quant x2 -> IDCT -> crop."""
    q2 = encode_lib.decode_blocks(
        encode_lib.EncodedBlocks(values=c.values, index=c.index)
    )
    params = quant_lib.QuantParams(fmin=c.fmin, fmax=c.fmax, bits=c.bits)
    coefs = quant_lib.dequantize_blocks(q2, params, c.level)
    x = dct_lib._unblockize(dct_lib.idct2_blocks(coefs))
    return dct_lib.crop_from_block(x, c.orig_hw).astype(dtype)


def roundtrip(x: jax.Array, policy: CompressionPolicy) -> jax.Array:
    """Lossy reconstruct — what the next layer actually consumes."""
    return decompress(compress(x, policy), x.dtype)


def compression_ratio(c: Compressed, orig_value_bits: int = 16) -> jax.Array:
    """Paper Eq. 20: compressed bits / original bits (lower = better).

    Compressed bits = 64 index bits per block + `bits` per non-zero (plus the
    per-tensor fmin/fmax header, negligible and ignored as in the paper).
    """
    import numpy as np

    nblocks = c.index.size // (BLOCK * BLOCK)
    nnz = jnp.sum(c.index)
    comp_bits = nblocks * BLOCK * BLOCK + nnz * c.bits
    h, w = c.orig_hw
    lead = int(np.prod(c.values.shape[:-4])) if c.values.ndim > 4 else 1
    orig_bits = lead * h * w * orig_value_bits
    return comp_bits / orig_bits


# ---------------------------------------------------------------------------
# TPU runtime path: structured frequency truncation (dense int8 carrier).
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class TruncatedCompressed:
    """(..., nh, nw, k, k) int8 low-frequency corners + per-tile scale/zero."""

    coefs: jax.Array       # int8
    scale: jax.Array       # (..., nh, nw, 1, 1) f32
    zero: jax.Array        # (..., nh, nw, 1, 1) f32  (range midpoint offset)
    keep: int
    orig_hw: tuple[int, int]

    def tree_flatten(self):
        return (self.coefs, self.scale, self.zero), (self.keep, self.orig_hw)

    @classmethod
    def tree_unflatten(cls, aux, children):
        coefs, scale, zero = children
        keep, orig_hw = aux
        return cls(coefs, scale, zero, keep, orig_hw)

    def nbytes_per_element(self) -> float:
        """Compressed bytes per original element (the runtime ratio)."""
        k = self.keep
        per_tile = k * k * 1 + 8  # int8 corner + f32 scale/zero header
        return per_tile / (BLOCK * BLOCK)


def compress_truncated(x: jax.Array, keep: int) -> TruncatedCompressed:
    """DCT -> per-tile symmetric int8 quant of the k x k low-frequency corner."""
    *_, h, w = x.shape
    padded, _ = dct_lib.pad_to_block(x)
    blocks = dct_lib._blockize(padded)
    coefs = dct_lib.dct2_blocks(blocks)
    corner = coefs[..., :keep, :keep]
    amax = jnp.max(jnp.abs(corner), axis=(-1, -2), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(corner / scale), -127, 127).astype(jnp.int8)
    zero = jnp.zeros_like(scale)
    return TruncatedCompressed(coefs=q, scale=scale, zero=zero, keep=keep, orig_hw=(h, w))


def decompress_truncated(c: TruncatedCompressed, dtype=jnp.float32) -> jax.Array:
    corner = c.coefs.astype(jnp.float32) * c.scale + c.zero
    full = jnp.zeros((*corner.shape[:-2], BLOCK, BLOCK), jnp.float32)
    full = full.at[..., : c.keep, : c.keep].set(corner)
    x = dct_lib._unblockize(dct_lib.idct2_blocks(full))
    return dct_lib.crop_from_block(x, c.orig_hw).astype(dtype)


def roundtrip_truncated(x: jax.Array, keep: int) -> jax.Array:
    return decompress_truncated(compress_truncated(x, keep), x.dtype)
