"""End-to-end interlayer feature-map codec (paper §III, Fig. 3/4).

Compatibility facade: the implementation lives in `repro.codec`, the unified
codec dispatch layer. Every call here routes through the codec backend
registry — pure-JAX `reference` everywhere, the fused Pallas kernels on TPU
(force a backend with the `backend=` argument, `REPRO_CODEC_BACKEND`, or
`repro.codec.set_default_backend`).

Two paths, as before:

* `compress` / `decompress` — the paper-exact pipeline:
      DCT -> min-max m-bit quant -> Q-table quant -> bitmap encode
  and its inverse (sparse *accounting* lives in encode.py).

* `compress_truncated` / `decompress_truncated` — the TPU runtime path
  (DESIGN.md §2): DCT -> min-max int8 -> keep only the k x k low-frequency
  corner, stored dense.  Fixed shapes, MXU-aligned, usable inside jit/remat/
  custom_vjp with zero host round-trips.  This is what ActCompress/KVCompress
  use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import codec as codec_lib
from repro.codec.api import (  # noqa: F401  (re-exported compatibility names)
    BLOCK,
    Compressed,
    CompressionPolicy,
    TruncatedCompressed,
    compression_ratio,
)


def compress(x: jax.Array, policy: CompressionPolicy,
             backend: str | None = None) -> Compressed:
    """Paper pipeline: pad -> blockize -> DCT -> quant x2 -> bitmap encode."""
    return codec_lib.paper_compress(x, policy, backend=backend)


def decompress(c: Compressed, dtype=jnp.float32,
               backend: str | None = None) -> jax.Array:
    """Inverse: decode -> inverse quant x2 -> IDCT -> crop."""
    return codec_lib.paper_decompress(c, dtype, backend=backend)


def roundtrip(x: jax.Array, policy: CompressionPolicy,
              backend: str | None = None) -> jax.Array:
    """Lossy reconstruct — what the next layer actually consumes."""
    return codec_lib.paper_roundtrip(x, policy, backend=backend)


def compress_truncated(x: jax.Array, keep: int,
                       backend: str | None = None) -> TruncatedCompressed:
    """DCT -> per-tile symmetric int8 quant of the k x k low-frequency corner."""
    return codec_lib.Codec(keep=keep, backend=backend).compress(x)


def decompress_truncated(c: TruncatedCompressed, dtype=jnp.float32,
                         backend: str | None = None) -> jax.Array:
    return codec_lib.Codec(keep=c.keep, backend=backend).decompress(c, dtype)


def roundtrip_truncated(x: jax.Array, keep: int,
                        backend: str | None = None) -> jax.Array:
    return codec_lib.Codec(keep=keep, backend=backend).roundtrip(x)
