"""8x8 block DCT-II / DCT-III (IDCT) — the paper's frequency transform (Eq. 2-6).

The paper uses the orthonormal DCT-II variant (first row scaled by 1/sqrt(2),
whole matrix scaled by sqrt(2/N)) so that C @ C.T == I and the 2-D transform is
Z = C X C^T (Eq. 5), X = C^T Z C (Eq. 6).

Also implements the Gong et al. [40] fast decomposition the paper's DCT module
uses in hardware (Eq. 12-18): the 8x8 transform splits into even/odd 4x4 halves
via butterflies, halving multiplies.  On TPU the plain 8x8 constant matmul is
already MXU-friendly, so the fast path exists as a *validated reference* of the
paper's hardware algorithm, not the default compute path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8


@functools.lru_cache(maxsize=None)
def _dct_matrix_np(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II matrix C with C[k, i] = s_k cos(pi (i + 1/2) k / n)."""
    k = np.arange(n)[:, None].astype(np.float64)
    i = np.arange(n)[None, :].astype(np.float64)
    c = np.cos(np.pi * (i + 0.5) * k / n)
    c *= np.sqrt(2.0 / n)
    c[0] *= 1.0 / np.sqrt(2.0)
    return c


def dct_matrix(n: int = BLOCK, dtype=jnp.float32) -> jax.Array:
    return jnp.asarray(_dct_matrix_np(n), dtype=dtype)


# ---------------------------------------------------------------------------
# Dense blocked 2-D DCT.  Input layout: (..., H, W) with H, W multiples of 8.
# ---------------------------------------------------------------------------

def _blockize(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """(..., H, W) -> (..., H/b, W/b, b, b)."""
    *lead, h, w = x.shape
    x = x.reshape(*lead, h // block, block, w // block, block)
    return jnp.moveaxis(x, -3, -2)


def _unblockize(x: jax.Array) -> jax.Array:
    """(..., H/b, W/b, b, b) -> (..., H, W)."""
    *lead, nh, nw, b, b2 = x.shape
    x = jnp.moveaxis(x, -2, -3)
    return x.reshape(*lead, nh * b, nw * b2)


def dct2_blocks(blocks: jax.Array, dtype=jnp.float32) -> jax.Array:
    """2-D DCT-II of (..., 8, 8) blocks: Z = C X C^T (Eq. 5)."""
    c = dct_matrix(blocks.shape[-1], dtype)
    x = blocks.astype(dtype)
    return jnp.einsum("ki,...ij,lj->...kl", c, x, c)


def idct2_blocks(coefs: jax.Array, dtype=jnp.float32) -> jax.Array:
    """2-D DCT-III of (..., 8, 8) blocks: X = C^T Z C (Eq. 6)."""
    c = dct_matrix(coefs.shape[-1], dtype)
    z = coefs.astype(dtype)
    return jnp.einsum("ik,...ij,jl->...kl", c, z, c)


def dct2(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """Blocked 2-D DCT over the trailing two axes (H, W multiples of `block`)."""
    return _unblockize(dct2_blocks(_blockize(x, block)))


def idct2(z: jax.Array, block: int = BLOCK) -> jax.Array:
    return _unblockize(idct2_blocks(_blockize(z, block)))


def pad_to_block(x: jax.Array, block: int = BLOCK) -> tuple[jax.Array, tuple[int, int]]:
    """Edge-pad trailing two dims up to a multiple of `block`.

    Edge padding (replicate border) avoids the artificial high-frequency step a
    zero-pad would inject at the boundary, matching JPEG practice.
    """
    *_, h, w = x.shape
    ph = (-h) % block
    pw = (-w) % block
    if ph or pw:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, ph), (0, pw)]
        x = jnp.pad(x, pad, mode="edge")
    return x, (ph, pw)


def crop_from_block(x: jax.Array, orig_hw: tuple[int, int]) -> jax.Array:
    h, w = orig_hw
    return x[..., :h, :w]


# ---------------------------------------------------------------------------
# Gong et al. [40] fast 8x8 DCT — the paper's hardware algorithm (Eq. 12-18).
#
# C = Q^T [[Ce, Ce P], [Co, -Co P]]  up to row permutation Q (Eq. 13/14): the
# even DCT rows act on x_top + reverse(x_bottom), the odd rows on
# x_top - reverse(x_bottom).  One 8-pt transform = two 4x4 matmuls.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _gong_matrices() -> tuple[np.ndarray, np.ndarray]:
    c = _dct_matrix_np(8)
    ce = c[0::2, :4]  # even rows are symmetric: c[2k, i] == c[2k, 7-i]
    co = c[1::2, :4]  # odd rows antisymmetric: c[2k+1, i] == -c[2k+1, 7-i]
    return ce, co


def dct1d_8_fast(x: jax.Array) -> jax.Array:
    """8-point DCT-II along the last axis via the even/odd 4x4 decomposition."""
    ce, co = _gong_matrices()
    ce = jnp.asarray(ce, x.dtype)
    co = jnp.asarray(co, x.dtype)
    top, bot = x[..., :4], x[..., 4:]
    bot_r = bot[..., ::-1]
    even = (top + bot_r) @ ce.T  # X_0, X_2, X_4, X_6
    odd = (top - bot_r) @ co.T   # X_1, X_3, X_5, X_7
    out = jnp.stack([even, odd], axis=-1)  # interleave even/odd -> natural order
    return out.reshape(*x.shape[:-1], 8)


def dct2_blocks_fast(blocks: jax.Array, dtype=jnp.float32) -> jax.Array:
    """2-D DCT of (..., 8, 8) blocks using the Gong fast 1-D transform twice."""
    x = blocks.astype(dtype)
    y = dct1d_8_fast(x)                      # transform rows' last axis (W)
    y = jnp.swapaxes(y, -1, -2)
    y = dct1d_8_fast(y)                      # transform the H axis
    return jnp.swapaxes(y, -1, -2)


# ---------------------------------------------------------------------------
# Tiling helper for non-image tensors (LM activations): fold trailing dims to
# a 2-D (rows, cols) plane, DCT it, and restore.  rows = flattened leading of
# the last axis in groups of 8; see DESIGN.md §6(3).
# ---------------------------------------------------------------------------

def as_plane(x: jax.Array) -> tuple[jax.Array, tuple]:
    """Reshape any >=2-D tensor to (-1, last_dim) for 8x8 tiling."""
    shape = x.shape
    return x.reshape(-1, shape[-1]), shape


def from_plane(x: jax.Array, shape: tuple) -> jax.Array:
    return x.reshape(shape)
