"""Sparse-matrix encoding of quantized DCT blocks (paper §III-B, Fig. 5).

The paper's codec, bit-faithfully:
  * per 8x8 block, a 1-bit 8x8 index matrix marks non-zeros (64 bits of index
    per block, stored in a dedicated index buffer);
  * only non-zero values are stored in the feature-map buffer (8 SRAM banks,
    one per block row, written column-by-column);
  * consecutive blocks are row-FLIPPED so that a mostly-empty bottom row of one
    block packs against the mostly-full top row of the next (Fig. 5 c/d).

We model storage cost exactly: index bits + value bits, and SRAM bank
occupancy under the flip scheme (max over banks = occupied depth) vs. without
flipping, to reproduce the paper's utilization argument.

Baseline codecs for the Table IV/V comparison: plain bitmap on raw activations
(EIE-style [25]), run-length (Eyeriss JSSC'17 [23]), CSR/COO (STICKER [28]),
and the zero-order entropy bound (what ideal Huffman would reach).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8


@dataclass(frozen=True)
class EncodedBlocks:
    """Paper codec output for a batch of 8x8 blocks (dense carrier form).

    `values` keeps the dense (..., 8, 8) quantized ints (zeros included) so the
    representation stays fixed-shape for JAX; `index` is the 1-bit matrix. The
    *storage accounting* (what would be written to SRAM) is computed from these
    by `storage_bits`.
    """

    values: jax.Array  # (..., 8, 8) int32 quantized coefficients
    index: jax.Array   # (..., 8, 8) bool non-zero map

    @property
    def nnz(self) -> jax.Array:
        return jnp.sum(self.index)


def encode_blocks(q2: jax.Array) -> EncodedBlocks:
    index = q2 != 0
    return EncodedBlocks(values=q2.astype(jnp.int32), index=index)


def decode_blocks(enc: EncodedBlocks, dtype=jnp.float32) -> jax.Array:
    """Reconstruct dense quantized blocks (values already dense; mask anyway).

    The index matrix doubles as the zero-gate for the IDCT multipliers in the
    paper; here it guarantees decode(encode(x)) == x even if a carrier value
    under a zero index is garbage.
    """
    return jnp.where(enc.index, enc.values, 0).astype(dtype)


# ---------------------------------------------------------------------------
# Storage accounting (bits) — the compression-ratio numbers of Table III.
# ---------------------------------------------------------------------------

def paper_codec_bits(q2: np.ndarray, value_bits: int = 8) -> int:
    """Paper codec: 64 index bits + value_bits per non-zero, per 8x8 block."""
    q2 = np.asarray(q2)
    nblocks = q2.size // (BLOCK * BLOCK)
    nnz = int(np.count_nonzero(q2))
    return nblocks * BLOCK * BLOCK + nnz * value_bits


def dense_bits(x: np.ndarray, value_bits: int = 16) -> int:
    """Uncompressed activation storage (the paper's 16-bit fixed point)."""
    return int(np.asarray(x).size) * value_bits


def bitmap_codec_bits(x: np.ndarray, value_bits: int = 16) -> int:
    """Plain bitmap sparse codec on raw activations (EIE-style baseline)."""
    x = np.asarray(x)
    return x.size + int(np.count_nonzero(x)) * value_bits


def rle_codec_bits(x: np.ndarray, value_bits: int = 16, run_bits: int = 5) -> int:
    """Run-length coding of zeros (Eyeriss-style): each non-zero is stored as
    (zero-run-length, value); runs longer than 2**run_bits-1 emit a zero value.

    Vectorized over the zero-gap structure (each non-zero token is preceded by
    floor(gap / maxrun) saturated zero tokens; a trailing zero run costs
    ceil(run / maxrun) tokens) — a per-element Python loop crawls on
    real-size feature maps (benchmarks/codec_compare.py).
    """
    flat = np.asarray(x).reshape(-1)
    maxrun = (1 << run_bits) - 1
    nz_idx = np.flatnonzero(flat)
    # zero-gap before each non-zero (first gap measured from position 0)
    gaps = np.diff(nz_idx, prepend=-1) - 1
    tokens = nz_idx.size + int(np.sum(gaps // maxrun))
    tail = flat.size - (int(nz_idx[-1]) + 1 if nz_idx.size else 0)
    tokens += -(-tail // maxrun)  # ceil: trailing zero run
    return tokens * (run_bits + value_bits)


def rle_codec_bits_tiles(x, value_bits: int = 16, run_bits: int = 5):
    """`rle_codec_bits` per trailing-axis stream, jit-traceable.

    `x` is (..., n); every trailing vector is its own RLE stream and the
    result is the (...,) int32 bit count of each.  This is the SAME
    zero-gap accounting as `rle_codec_bits` above (each non-zero token is
    preceded by floor(gap / maxrun) saturated zero tokens; a trailing zero
    run costs ceil(run / maxrun) tokens), expressed in jnp so the bitplane
    codec family can store a measured per-block length scalar inside jit.
    tests pin the two functions bitwise against each other — this is the
    one traceable form of the reference, not a second accounting.
    """
    x = jnp.asarray(x)
    n = x.shape[-1]
    maxrun = (1 << run_bits) - 1
    mask = x != 0
    pos = jnp.arange(n, dtype=jnp.int32)
    marked = jnp.where(mask, pos, -1)
    # index of the previous non-zero at-or-before each position (-1 = none);
    # shifted right one step it is the previous non-zero STRICTLY before.
    prev_at = jax.lax.associative_scan(jnp.maximum, marked, axis=-1)
    prev_before = jnp.concatenate(
        [jnp.full(x.shape[:-1] + (1,), -1, jnp.int32), prev_at[..., :-1]],
        axis=-1)
    gaps = pos - prev_before - 1                       # zeros before position
    saturated = jnp.where(mask, gaps // maxrun, 0)     # zero tokens per nnz
    nnz = jnp.sum(mask, axis=-1)
    tail = n - 1 - jnp.max(marked, axis=-1)            # trailing zero run
    tokens = nnz + jnp.sum(saturated, axis=-1) + (-(-tail // maxrun))
    return (tokens * (run_bits + value_bits)).astype(jnp.int32)


def csr_codec_bits(x: np.ndarray, value_bits: int = 16) -> int:
    """CSR over 2-D planes: col index per nnz + row pointers (STICKER-style)."""
    x = np.asarray(x)
    x2 = x.reshape(-1, x.shape[-1])
    rows, cols = x2.shape
    col_bits = max(1, int(np.ceil(np.log2(max(cols, 2)))))
    ptr_bits = max(1, int(np.ceil(np.log2(max(x2.size, 2)))))
    nnz = int(np.count_nonzero(x2))
    return nnz * (value_bits + col_bits) + (rows + 1) * ptr_bits


def entropy_bound_bits(x: np.ndarray) -> float:
    """Zero-order entropy of the symbol stream — ideal Huffman lower bound."""
    flat = np.asarray(x).reshape(-1)
    _, counts = np.unique(flat, return_counts=True)
    p = counts / flat.size
    h = -np.sum(p * np.log2(p))
    return float(h * flat.size)


# ---------------------------------------------------------------------------
# Flip-storage SRAM bank model (Fig. 5) — utilization accounting only.
# ---------------------------------------------------------------------------

def sram_bank_occupancy(index: np.ndarray, flip: bool = True) -> tuple[int, int]:
    """Model the 8-bank feature-map buffer.

    Bank r accumulates the non-zeros of block-row r; with `flip`, every odd
    block is row-reversed before banking (Fig. 5c).  Returns
    (occupied_depth = max bank fill, total_nnz).  Utilization = nnz / (8 * depth).

    Vectorized over the whole block batch (row-sum, flip the odd blocks'
    row axis, sum over blocks) — the former per-block Python loop crawled
    on real-size feature maps the same way `rle_codec_bits` used to.
    """
    idx = np.asarray(index, dtype=bool).reshape(-1, BLOCK, BLOCK)
    nnz = int(idx.sum())
    if not len(idx):
        return 0, nnz
    row_nnz = idx.sum(axis=2, dtype=np.int64)      # (nblocks, 8) per-row fill
    if flip:
        row_nnz[1::2] = row_nnz[1::2, ::-1]        # odd blocks bank reversed
    fills = row_nnz.sum(axis=0)
    return int(fills.max()), nnz


def sram_utilization(index: np.ndarray, flip: bool = True) -> float:
    depth, nnz = sram_bank_occupancy(index, flip)
    if depth == 0:
        return 1.0
    return nnz / (BLOCK * depth)
