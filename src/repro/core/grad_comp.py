"""GradCompress: DCT-truncated, error-feedback gradient exchange (DESIGN.md §3.3).

Cross-pod data parallelism reduces gradients over the slowest links in the
system. The paper's idea — transform at the memory/transport boundary so the
expensive level only ever sees frequency-truncated int8 — applied to that
all-reduce:

  1. error feedback:  g_fb = g + residual          (carried per-leaf state)
  2. compress:        per-leaf (rows, cols) plane -> 8x8 DCT tiles ->
                      per-tile TOP-K |coefficient| -> int8 values + u8 indices
  3. exchange:        all_gather the int8 payload over the `pod` axis
                      (wire ~ (2k^2+4)/256 of f32: k=5 -> ~4.7x less)
  4. decompress+mean: each pod reconstructs every pod's contribution, averages
  5. residual update: residual' = g_fb - decompress(compress(g_fb))

Why top-k support and not the paper's fixed low-frequency corner: error
feedback REQUIRES a contractive compressor (||x - C(x)|| <= (1-k/64)||x||,
which magnitude top-k satisfies). A FIXED subspace projection is idempotent:
the orthogonal component re-enters the residual unchanged every step and the
residual norm grows LINEARLY (measured: 59 -> 2368 over 40 steps) while the
reconstructed mean never improves — the paper's corner truncation is correct
for activations (consumed once) but wrong for accumulated gradient state.
Both modes are implemented; tests/test_grad_comp.py pins the divergence of
`corner` and the convergence of `topk` (EXPERIMENTS.md §Perf, refuted-
hypothesis log).

The exchange runs inside a partial-manual shard_map over `pod` (data/model
axes stay in GSPMD auto mode), so the collective schedule in the lowered HLO
shows int8 all-gathers on the pod axis instead of f32 all-reduces — the
claim the roofline's collective term verifies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dct as dct_lib

BLOCK = 8
MIN_COMPRESS_SIZE = 64 * 64  # leaves smaller than this go raw (headers dominate)


def _compressible(leaf: jax.Array) -> bool:
    if leaf.ndim < 2 or leaf.size < MIN_COMPRESS_SIZE:
        return False
    rows = int(np.prod(leaf.shape[:-1]))
    return rows % BLOCK == 0 and leaf.shape[-1] % BLOCK == 0


def _dct_k(keep: int) -> jax.Array:
    return jnp.asarray(dct_lib._dct_matrix_np(BLOCK)[:keep], jnp.float32)


def _dct8_full() -> jax.Array:
    return jnp.asarray(dct_lib._dct_matrix_np(BLOCK), jnp.float32)


def _tiles(g: jax.Array) -> jax.Array:
    """(rows, cols) plane -> full-DCT tiles (nr, nc, 64) f32."""
    plane = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    r, c = plane.shape
    cm = _dct8_full()
    t = plane.reshape(r // BLOCK, BLOCK, c // BLOCK, BLOCK)
    t = jnp.swapaxes(t, 1, 2)
    z = jnp.einsum("ua,ijab,vb->ijuv", cm, t, cm)
    return z.reshape(z.shape[0], z.shape[1], BLOCK * BLOCK)


def _untile(z64: jax.Array, shape) -> jax.Array:
    nr, nc, _ = z64.shape
    cm = _dct8_full()
    z = z64.reshape(nr, nc, BLOCK, BLOCK)
    t = jnp.einsum("ua,ijuv,vb->ijab", cm, z, cm)
    plane = jnp.swapaxes(t, 1, 2).reshape(nr * BLOCK, nc * BLOCK)
    return plane.reshape(shape)


def compress_leaf(g: jax.Array, keep: int, mode: str = "topk"):
    """(rows, cols) plane -> per-8x8-tile compressed DCT coefficients.

    mode="topk": keep^2 largest-|.| coefficients per tile (contractive —
    required under error feedback). Returns (values int8 (nr,nc,K),
    indices u8 (nr,nc,K), scale f32 (nr,nc)).
    mode="corner": the paper's fixed k x k low-frequency corner (indices are
    a constant; returned anyway for a uniform interface).
    """
    z = _tiles(g)                                        # (nr, nc, 64)
    kk = keep * keep
    if mode == "corner":
        ii = (jnp.arange(BLOCK)[:, None] * BLOCK + jnp.arange(BLOCK)[None, :])
        idx_const = ii[:keep, :keep].reshape(-1)         # (kk,)
        vals = z[..., idx_const]
        idx = jnp.broadcast_to(idx_const.astype(jnp.uint8), vals.shape)
    else:
        mag = jnp.abs(z)
        _, top_idx = jax.lax.top_k(mag, kk)              # (nr, nc, kk)
        vals = jnp.take_along_axis(z, top_idx, axis=-1)
        idx = top_idx.astype(jnp.uint8)
    amax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
    return q, idx, scale[..., 0]


def decompress_leaf(q: jax.Array, idx: jax.Array, scale: jax.Array, shape,
                    dtype=jnp.float32) -> jax.Array:
    nr, nc, kk = q.shape
    vals = q.astype(jnp.float32) * scale[..., None]
    z = jnp.zeros((nr, nc, BLOCK * BLOCK), jnp.float32)
    z = jnp.put_along_axis(z, idx.astype(jnp.int32), vals, axis=-1,
                           inplace=False)
    return _untile(z, shape).astype(dtype)


# ---------------------------------------------------------------------------
# Error-feedback state
# ---------------------------------------------------------------------------

def init_residual(params: Any) -> Any:
    """Zero residual for every compressible leaf; None markers elsewhere."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if _compressible(p) else jnp.zeros((), jnp.float32),
        params,
    )


@dataclass(frozen=True)
class GradCompressConfig:
    keep: int = 5          # keep^2 coefficients per 8x8 tile
    mode: str = "topk"     # topk (EF-safe) | corner (paper-faithful; diverges
                           # under EF — kept for the ablation)
    enabled: bool = True


# ---------------------------------------------------------------------------
# The cross-pod exchange (call INSIDE shard_map with a manual 'pod' axis)
# ---------------------------------------------------------------------------

def exchange_compressed(grads: Any, residual: Any, cfg: GradCompressConfig,
                        axis: str = "pod") -> tuple[Any, Any]:
    """All-reduce `grads` over `axis` in compressed form with error feedback.

    Returns (mean_grads, new_residual). Must run where `axis` is a manual
    (shard_map) axis; data/model sharding of the leaves themselves may remain
    under GSPMD auto mode.
    """
    flat, treedef = jax.tree.flatten(grads)
    res_flat = jax.tree.leaves(residual)
    out, new_res = [], []
    for g, r in zip(flat, res_flat):
        if not _compressible(g):
            out.append(jax.lax.pmean(g, axis))
            new_res.append(r)
            continue
        g_fb = g.astype(jnp.float32) + r
        q, idx, scale = compress_leaf(g_fb, cfg.keep, cfg.mode)
        # wire payload: int8 values + u8 indices + f32 scale, every pod
        q_all = jax.lax.all_gather(q, axis)          # (npod, ...)
        i_all = jax.lax.all_gather(idx, axis)
        s_all = jax.lax.all_gather(scale, axis)
        approx_own = decompress_leaf(q, idx, scale, g.shape)
        total = jnp.zeros(g.shape, jnp.float32)
        npod = q_all.shape[0]
        for i in range(npod):  # npod is small (2); unrolled decompress-sum
            total = total + decompress_leaf(q_all[i], i_all[i], s_all[i], g.shape)
        out.append((total / npod).astype(g.dtype))
        new_res.append(g_fb - approx_own)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, new_res)


def plain_exchange(grads: Any, axis: str = "pod") -> Any:
    """Uncompressed baseline: f32 pmean over the pod axis."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)


def wire_bytes(params: Any, cfg: GradCompressConfig) -> dict[str, float]:
    """Analytic wire bytes per step for compressed vs raw exchange."""
    raw = 0
    comp = 0
    for p in jax.tree.leaves(params):
        raw += p.size * 4
        if _compressible(p):
            ntiles = p.size // (BLOCK * BLOCK)
            per_tile = cfg.keep * cfg.keep * (2 if cfg.mode == "topk" else 1) + 4
            comp += ntiles * per_tile
        else:
            comp += p.size * 4
    return {"raw_bytes": float(raw), "compressed_bytes": float(comp),
            "ratio": comp / max(raw, 1)}
