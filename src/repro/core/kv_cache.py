"""KVCompress: DCT-truncated int8 KV cache (DESIGN.md §3.2).

The paper stores interlayer feature maps compressed so the expensive memory
level never holds raw data. In serving, the analogous storage is the KV
cache: at 32k-512k contexts it dominates HBM capacity AND decode-step HBM
bandwidth (every step re-reads the whole cache).

Layout: per (layer, batch, kv-head) the (S, hd) plane is tiled into 8x8
(seq-block x feature-block) tiles; each tile keeps only its top-left k x k
low-frequency DCT corner as int8 with a per-tile f32 scale:

  packed : (L, B, S/8, hd/8, k, k) int8
  scale  : (L, B, S/8, hd/8)       f32

Compressed bytes/elem = (k*k + 4) / 64 vs 2 (bf16): k=4 -> 0.31 B (6.4x),
k=6 -> 0.63 B (3.2x).  Because decode is memory-bound, the bandwidth saving
is the same factor — that is the paper's DMA-bandwidth argument verbatim.

Decode appends single tokens, which don't fill an 8-token seq block, so the
cache keeps a RAW TAIL of up to 8 tokens; when the tail fills, the whole
block is DCT-compressed into the packed store (lax.cond, fixed shapes).
Attention consumes the packed store via `attend_compressed`, which
decompresses per KV chunk INSIDE the flash-attention scan — the HBM traffic
for history is int8 packed + scales only, mirroring the paper's "IDCT fused
into the PE stream".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import codec as codec_lib

BLOCK = 8


# ---------------------------------------------------------------------------
# Tile codec on (S, hd) planes with arbitrary leading dims — thin wrappers
# over the unified codec dispatch (reference einsum on CPU, fused Pallas on
# TPU; override via backend=/REPRO_CODEC_BACKEND).
# ---------------------------------------------------------------------------

def compress_kv_blocks(x: jax.Array, keep: int,
                       backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (..., S, hd) with S % 8 == 0, hd % 8 == 0.

    Returns (packed (..., S/8, hd/8, k, k) int8, scale (..., S/8, hd/8) f32).
    """
    return codec_lib.compress_blocks(x, keep, backend=backend)


def decompress_kv_blocks(packed: jax.Array, scale: jax.Array, dtype=jnp.bfloat16,
                         backend: str | None = None) -> jax.Array:
    """Inverse of compress_kv_blocks -> (..., S, hd)."""
    return codec_lib.decompress_blocks(packed, scale, out_dtype=dtype,
                                       backend=backend)


# ---------------------------------------------------------------------------
# Cache container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class CompressedKVCache:
    """Per-model compressed KV store + raw 8-token tail ring.

    Shapes (GQA):
      packed_k/v : (L, B, S/8, Hkv, hd/8, k, k) int8
      scale_k/v  : (L, B, S/8, Hkv, hd/8)       f32
      tail_k/v   : (L, B, 8, Hkv, hd)           raw dtype
    """

    packed_k: jax.Array
    scale_k: jax.Array
    packed_v: jax.Array
    scale_v: jax.Array
    tail_k: jax.Array
    tail_v: jax.Array
    keep: int

    def tree_flatten(self):
        return (
            self.packed_k, self.scale_k, self.packed_v, self.scale_v,
            self.tail_k, self.tail_v,
        ), (self.keep,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, keep=aux[0])

    @property
    def max_seq(self) -> int:
        return self.packed_k.shape[2] * BLOCK

    def nbytes_per_token_per_layer(self) -> float:
        """Compressed bytes per token per layer (both K and V)."""
        _, _, _, hkv, nhd, k, _ = self.packed_k.shape
        per_block = hkv * nhd * (k * k + 4)  # int8 corner + f32 scale
        return 2 * per_block / BLOCK


def init_compressed_cache(cfg, batch: int, max_seq: int, keep: int = 4,
                          dtype=jnp.bfloat16) -> CompressedKVCache:
    assert max_seq % BLOCK == 0
    hd = cfg.resolved_head_dim
    assert hd % BLOCK == 0, f"head_dim {hd} not 8-tileable"
    l, hkv = cfg.n_layers, cfg.n_kv_heads
    ns, nh = max_seq // BLOCK, hd // BLOCK
    mk = lambda: jnp.zeros((l, batch, ns, hkv, nh, keep, keep), jnp.int8)
    sc = lambda: jnp.zeros((l, batch, ns, hkv, nh), jnp.float32)
    tl = lambda: jnp.zeros((l, batch, BLOCK, hkv, hd), dtype)
    return CompressedKVCache(mk(), sc(), mk(), sc(), tl(), tl(), keep)


# ---------------------------------------------------------------------------
# Per-layer decode update (operates on the [B, ...] slices for one layer)
# ---------------------------------------------------------------------------

def update_layer(
    layer_cache: dict[str, jax.Array],
    k_new: jax.Array,  # (B, 1, Hkv, hd)
    v_new: jax.Array,
    pos: jax.Array,    # scalar absolute position of the new token
    keep: int,
) -> dict[str, jax.Array]:
    """Write the new token into the tail; flush the block when it fills.

    layer_cache keys: packed_k/scale_k/packed_v/scale_v (B, S/8, Hkv, hd/8, k, k)
    / (B, S/8, Hkv, hd/8), tail_k/tail_v (B, 8, Hkv, hd).
    """
    slot = jnp.mod(pos, BLOCK)
    tail_k = jax.lax.dynamic_update_slice(
        layer_cache["tail_k"], k_new.astype(layer_cache["tail_k"].dtype), (0, slot, 0, 0)
    )
    tail_v = jax.lax.dynamic_update_slice(
        layer_cache["tail_v"], v_new.astype(layer_cache["tail_v"].dtype), (0, slot, 0, 0)
    )

    def flush(args):
        pk, sk, pv, sv, tk, tv = args
        blk = pos // BLOCK
        # (B, 8, Hkv, hd) -> (B, Hkv, 8, hd) planes -> compress
        qk, sck = compress_kv_blocks(jnp.swapaxes(tk, 1, 2), keep)
        qv, scv = compress_kv_blocks(jnp.swapaxes(tv, 1, 2), keep)
        # qk: (B, Hkv, 1, hd/8, k, k) -> cache layout (B, 1, Hkv, hd/8, k, k)
        qk = jnp.swapaxes(qk, 1, 2)
        qv = jnp.swapaxes(qv, 1, 2)
        sck = jnp.swapaxes(sck, 1, 2)
        scv = jnp.swapaxes(scv, 1, 2)
        pk = jax.lax.dynamic_update_slice(pk, qk, (0, blk, 0, 0, 0, 0))
        sk = jax.lax.dynamic_update_slice(sk, sck, (0, blk, 0, 0))
        pv = jax.lax.dynamic_update_slice(pv, qv, (0, blk, 0, 0, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, scv, (0, blk, 0, 0))
        return pk, sk, pv, sv

    def keep_tail(args):
        pk, sk, pv, sv, _, _ = args
        return pk, sk, pv, sv

    pk, sk, pv, sv = jax.lax.cond(
        slot == BLOCK - 1,
        flush,
        keep_tail,
        (
            layer_cache["packed_k"], layer_cache["scale_k"],
            layer_cache["packed_v"], layer_cache["scale_v"],
            tail_k, tail_v,
        ),
    )
    return dict(packed_k=pk, scale_k=sk, packed_v=pv, scale_v=sv,
                tail_k=tail_k, tail_v=tail_v)


# ---------------------------------------------------------------------------
# Flash attention over the compressed store (decode: Sq == 1)
# ---------------------------------------------------------------------------

def _repeat_heads(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, Hkv, S, hd) -> (B, Hkv*n_rep, S, hd)."""
    if n_rep == 1:
        return x
    b, hkv, s, hd = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, hkv, n_rep, s, hd)).reshape(b, hkv * n_rep, s, hd)


def attend_compressed(
    q: jax.Array,                 # (B, 1, H, hd)
    layer_cache: dict[str, jax.Array],
    pos: jax.Array,
    keep: int,
    *,
    kv_block: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Online-softmax decode attention where K/V history is decompressed per
    chunk INSIDE the scan — compressed bytes are what stream from HBM.

    The raw tail (positions pos - pos%8 .. pos) is attended separately and
    merged with the same running-max algebra.
    """
    b, sq, h, hd = q.shape
    pk = layer_cache["packed_k"]
    _, nblocks_total, hkv, nhd, k, _ = pk.shape
    n_rep = h // hkv
    max_seq = nblocks_total * BLOCK
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kv_block = min(kv_block, max_seq)
    while max_seq % kv_block:  # shrink to a divisor (max_seq is a mult of 8)
        kv_block -= BLOCK
    assert kv_block % BLOCK == 0 and kv_block > 0
    bpc = kv_block // BLOCK
    nchunks = max_seq // kv_block

    qf = (q.astype(jnp.float32) * scale)[:, 0]           # (B, H, hd)
    flushed = (pos // BLOCK) * BLOCK                      # tokens in packed store

    def chunk_body(carry, c):
        m, l, acc = carry
        start = c * bpc
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, bpc, 1)
        # planes per (B, Hkv): (B, nb, Hkv, ...) -> (B, Hkv, nb, ...)
        kc = decompress_kv_blocks(
            jnp.swapaxes(sl(layer_cache["packed_k"]), 1, 2),
            jnp.swapaxes(sl(layer_cache["scale_k"]), 1, 2), jnp.float32,
        )                                                 # (B, Hkv, kv_block, hd)
        vc = decompress_kv_blocks(
            jnp.swapaxes(sl(layer_cache["packed_v"]), 1, 2),
            jnp.swapaxes(sl(layer_cache["scale_v"]), 1, 2), jnp.float32,
        )
        kr = _repeat_heads(kc, n_rep)                     # (B, H, kv_block, hd)
        vr = _repeat_heads(vc, n_rep)
        kv_pos = start * BLOCK + jnp.arange(kv_block)
        valid = kv_pos < flushed                          # only flushed blocks
        s = jnp.einsum("bhd,bhkd->bhk", qf, kr)
        s = jnp.where(valid[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid[None, None], jnp.exp(s - m_safe[..., None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhk,bhkd->bhd", p, vr)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    acc0 = jnp.zeros((b, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk_body, (m0, l0, acc0), jnp.arange(nchunks))

    # ---- raw tail: positions flushed .. pos (inclusive) -------------------
    tk = jnp.swapaxes(layer_cache["tail_k"], 1, 2).astype(jnp.float32)  # (B,Hkv,8,hd)
    tv = jnp.swapaxes(layer_cache["tail_v"], 1, 2).astype(jnp.float32)
    tkr = _repeat_heads(tk, n_rep)
    tvr = _repeat_heads(tv, n_rep)
    tail_pos = flushed + jnp.arange(BLOCK)
    tvalid = tail_pos <= pos
    st = jnp.einsum("bhd,bhkd->bhk", qf, tkr)
    st = jnp.where(tvalid[None, None], st, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(st, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    pt = jnp.where(tvalid[None, None], jnp.exp(st - m_safe[..., None]), 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l = l * alpha + jnp.sum(pt, axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum("bhk,bhkd->bhd", pt, tvr)

    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, H, hd)
    return out[:, None].astype(q.dtype)           # (B, 1, H, hd)


def attend_auto(
    q: jax.Array,
    layer_cache: dict[str, jax.Array],
    pos: jax.Array,
    keep: int,
    *,
    kv_block: int = 1024,
    backend: str | None = None,
) -> jax.Array:
    """Backend-dispatched decode attention over the compressed store.

    `pallas` routes to the fused decompress+attend kernel (int8 blocks are
    what stream from HBM; the IDCT runs in VMEM); `reference` (and any other
    backend) uses the pure-JAX online-softmax scan above. Selection follows
    repro.codec.dispatch, same as the block codec itself.
    """
    if codec_lib.resolve_backend_name(backend) == "pallas":
        from repro.kernels.fused_attend import ops as fa_ops

        return fa_ops.attend_with_tail(q, layer_cache, pos, tile_s=kv_block)
    return attend_compressed(q, layer_cache, pos, keep, kv_block=kv_block)


# ---------------------------------------------------------------------------
# Bulk prefill: compress a whole prompt's K/V at once
# ---------------------------------------------------------------------------

def prefill_compress(
    k: jax.Array,  # (B, S, Hkv, hd), S % 8 == 0
    v: jax.Array,
    keep: int,
) -> dict[str, jax.Array]:
    """Compress a full prompt's K/V for one layer into cache layout."""
    kq, ks = compress_kv_blocks(jnp.swapaxes(k, 1, 2), keep)  # (B,Hkv,S/8,hd/8,k,k)
    vq, vs = compress_kv_blocks(jnp.swapaxes(v, 1, 2), keep)
    return dict(
        packed_k=jnp.swapaxes(kq, 1, 2), scale_k=jnp.swapaxes(ks, 1, 2),
        packed_v=jnp.swapaxes(vq, 1, 2), scale_v=jnp.swapaxes(vs, 1, 2),
    )
