"""KVCompress: DCT-truncated int8 KV cache (DESIGN.md §3.2).

The paper stores interlayer feature maps compressed so the expensive memory
level never holds raw data. In serving, the analogous storage is the KV
cache: at 32k-512k contexts it dominates HBM capacity AND decode-step HBM
bandwidth (every step re-reads the whole cache).

Layout: per (layer, batch, kv-head) the (S, hd) plane is tiled into 8x8
(seq-block x feature-block) tiles; each tile keeps only its top-left k x k
low-frequency DCT corner as int8 with a per-tile f32 scale:

  packed : (L, B, S/8, hd/8, k, k) int8
  scale  : (L, B, S/8, hd/8)       f32

Compressed bytes/elem = (k*k + 4) / 64 vs 2 (bf16): k=4 -> 0.31 B (6.4x),
k=6 -> 0.63 B (3.2x).  Because decode is memory-bound, the bandwidth saving
is the same factor — that is the paper's DMA-bandwidth argument verbatim.

The kept corner size k is PER LAYER: a `repro.codec.plan.CompressionPlan`
resolves a `LayerPolicy` per layer index (the paper's per-layer 2-bit
compression-level register), and the cache materializes it as a tuple of
`KVSegment`s — one stacked store per contiguous run of layers with equal
policy, each with its own (k, k) block geometry.  Uniform plans collapse to
a single segment, and the legacy `keep=` scalar is a one-line shim for
`CompressionPlan.uniform(keep)`.

Decode appends single tokens, which don't fill an 8-token seq block, so the
cache keeps a RAW TAIL of up to 8 tokens; when the tail fills, the whole
block is DCT-compressed into the packed store.  Positions are PER SLOT:
`pos` is a (B,) vector (scalars broadcast), so each batch row has its own
tail slot, its own flush decision (scatter writes with masked row indices;
one global cond only skips the codec when no row flushes), and its own
causal validity mask.  This is what lets the serve
engine retire and re-admit requests slot-by-slot (continuous batching) over
one shared compressed pool — the serving analogue of the paper's dynamic
feature-map buffer allocation.
Attention consumes the packed store via `attend_compressed`, which
decompresses per KV chunk INSIDE the flash-attention scan — the HBM traffic
for history is int8 packed + scales only, mirroring the paper's "IDCT fused
into the PE stream".

PAGED POOL (the paper's dynamic feature-map buffer allocation, literally):
instead of a dense per-slot `(B, S/8, ...)` store provisioned for max_seq,
`PagedKVCache` keeps a shared page pool whose page unit is ONE 8-token DCT
block group across all layers — per segment `packed_* (Lseg, P, Hkv, hd/8,
k, k)` — addressed through a per-slot block table `(B, S/8) -> page id`.
Because every layer of a slot flushes the same block index at the same step
(one position vector drives them all), a single block-table entry covers
all layers.  Pages are assigned by the HOST (the serve engine owns the free
list — allocation policy never enters the jit); the device only scatters
through the page index it is handed (`flush_page`) and gathers history
through the block table.  Unmapped table entries stay 0 — a valid page —
and are never read because attention masks `kv_pos < flushed` first.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import codec as codec_lib
from repro.codec import families as families_lib
from repro.codec import plan as plan_lib
from repro.parallel.sharding import (attn_hint, logical as shard_hint,
                                     table_slice_hint)

BLOCK = 8

# raw per-slot tail ring planes — outside every codec family's plane tree
TAIL_NAMES = families_lib.TAIL_NAMES


def block_group_bytes(keep: int, n_kv_heads: int, head_dim: int,
                      codec: str = "dct") -> int:
    """Analytic bytes of one flushed 8-token block group for ONE layer, K
    and V — the codec family's `analytic_tile_bytes` applied to the cache
    geometry (for dct exactly `codec.api.tile_bytes`).  This is the
    page-size unit of the paged pool and the per-block term of every
    analytic pool report."""
    assert head_dim % BLOCK == 0, head_dim
    fam = families_lib.get_family(codec)
    return 2 * n_kv_heads * (head_dim // BLOCK) * fam.analytic_tile_bytes(keep)


def as_pos_vec(pos: jax.Array | int, batch: int) -> jax.Array:
    """Normalize a position argument to a per-slot (B,) int32 vector.

    Scalars (the legacy lock-step API) broadcast to every row; (B,) vectors
    pass through, giving each batch slot its own absolute position.
    """
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos, (batch,))
    assert pos.shape == (batch,), (pos.shape, batch)
    return pos


# ---------------------------------------------------------------------------
# Tile codec on (S, hd) planes with arbitrary leading dims — thin wrappers
# over the unified codec dispatch (reference einsum on CPU, fused Pallas on
# TPU; override via backend=/REPRO_CODEC_BACKEND).
# ---------------------------------------------------------------------------

def compress_kv_blocks(x: jax.Array, keep: int,
                       backend: str | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (..., S, hd) with S % 8 == 0, hd % 8 == 0.

    Returns (packed (..., S/8, hd/8, k, k) int8, scale (..., S/8, hd/8) f32).
    """
    return codec_lib.compress_blocks(x, keep, backend=backend)


def decompress_kv_blocks(packed: jax.Array, scale: jax.Array, dtype=jnp.bfloat16,
                         backend: str | None = None) -> jax.Array:
    """Inverse of compress_kv_blocks -> (..., S, hd)."""
    return codec_lib.decompress_blocks(packed, scale, out_dtype=dtype,
                                       backend=backend)


# ---------------------------------------------------------------------------
# Cache container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
@dataclass
class KVSegment:
    """Compressed store for one contiguous run of layers sharing a policy.

    `planes` holds every storage array this segment's codec FAMILY declares,
    materialized once for K and once for V as ``{name}_k`` / ``{name}_v``
    (plus the family-independent raw tail ring ``tail_k`` / ``tail_v``).
    Shapes (GQA; Lseg = stop - start layers; block_shape per
    `families.PlaneSpec`):

      {name}_k/v : (Lseg, B, S/8, Hkv) + block_shape   e.g. dct packed ->
                   (Lseg, B, S/8, Hkv, hd/8, k, k) int8, scale ->
                   (Lseg, B, S/8, Hkv, hd/8) f32
      tail_k/v   : (Lseg, B, 8, Hkv, hd) raw dtype

    Registered WITH key paths so `parallel.sharding.cache_specs` can
    dispatch on each plane's name straight off the cache pytree — one spec
    rule set covers the dict form (dry-run) and the segment form (serve
    engine).  Flatten order is sorted-by-name so segments of equal plan are
    structurally equal pytrees.
    """

    planes: dict[str, jax.Array]
    keep: int                  # static: this segment's kept corner size
    start: int                 # static: absolute first layer
    stop: int                  # static: absolute one-past-last layer
    backend: str | None = None  # static: codec backend (None = auto)
    codec: str = "dct"          # static: codec family (plane tree owner)

    def __post_init__(self):
        # legacy positional-array construction died with _SEGMENT_FIELDS
        assert isinstance(self.planes, dict), type(self.planes)

    def _names(self) -> tuple[str, ...]:
        return tuple(sorted(self.planes))

    def tree_flatten(self):
        names = self._names()
        return tuple(self.planes[n] for n in names), \
            (names, self.keep, self.start, self.stop, self.backend,
             self.codec)

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        names = self._names()
        return tuple((ga(n), self.planes[n]) for n in names), \
            (names, self.keep, self.start, self.stop, self.backend,
             self.codec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, *rest = aux
        return cls(dict(zip(names, children)), *rest)

    # legacy single-plane views (the pre-family 4+2 field names)
    packed_k = property(lambda self: self.planes["packed_k"])
    scale_k = property(lambda self: self.planes["scale_k"])
    packed_v = property(lambda self: self.planes["packed_v"])
    scale_v = property(lambda self: self.planes["scale_v"])
    tail_k = property(lambda self: self.planes["tail_k"])
    tail_v = property(lambda self: self.planes["tail_v"])

    @property
    def family(self) -> families_lib.CodecFamily:
        return families_lib.get_family(self.codec)

    @property
    def page_keys(self) -> tuple[str, ...]:
        """Names of the block planes that live in the paged pool (every
        plane the family declares; tails stay per slot)."""
        return tuple(n for n in self._names() if n not in TAIL_NAMES)

    def as_tree(self) -> dict[str, jax.Array]:
        """The {packed_k, ..., tail_v} dict layer-sliceable consumers scan."""
        return dict(self.planes)

    def replace_arrays(self, tree: dict[str, jax.Array]) -> "KVSegment":
        assert sorted(tree) == list(self._names()), (sorted(tree), self._names())
        return KVSegment(dict(tree), self.keep, self.start, self.stop,
                         self.backend, self.codec)

    def nbytes(self) -> float:
        """Device bytes held by this segment's planes — the literal sum of
        the array buffers.  For the dct family this equals the analytic
        `codec.api.tile_bytes` charge exactly (int8 corner + 4-byte f32
        scale header, nothing else), so the pool report cannot drift from
        the codec accounting; tests/test_plan.py pins that identity.
        """
        return float(sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                         for a in self.planes.values()))


@jax.tree_util.register_pytree_with_keys_class
@dataclass
class CompressedKVCache:
    """Per-model compressed KV store: a tuple of per-policy `KVSegment`s.

    A uniform plan yields exactly one segment; the `packed_k`/.../`keep`
    properties then expose its planes directly (the legacy single-store
    view most tests and single-layer consumers use).  Non-uniform plans
    have per-segment block geometry — iterate `segments`.
    """

    segments: tuple[KVSegment, ...]

    def tree_flatten(self):
        return (self.segments,), ()

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("segments"), self.segments),), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children[0]))

    @classmethod
    def from_arrays(cls, packed_k, scale_k, packed_v, scale_v, tail_k, tail_v,
                    keep: int, backend: str | None = None) -> "CompressedKVCache":
        """Single-segment (uniform-plan, dct) cache from bare (L, B, ...)
        planes — the legacy constructor shape, for consumers that flatten
        the cache into its planes and rebuild it (e.g. the dry-run sharding
        driver)."""
        planes = dict(packed_k=packed_k, scale_k=scale_k, packed_v=packed_v,
                      scale_v=scale_v, tail_k=tail_k, tail_v=tail_v)
        return cls((KVSegment(planes, keep=keep, start=0,
                              stop=packed_k.shape[0], backend=backend),))

    def _single(self) -> KVSegment:
        if len(self.segments) != 1:
            raise ValueError(
                "cache has per-layer block geometry; iterate cache.segments")
        return self.segments[0]

    packed_k = property(lambda self: self._single().packed_k)
    scale_k = property(lambda self: self._single().scale_k)
    packed_v = property(lambda self: self._single().packed_v)
    scale_v = property(lambda self: self._single().scale_v)
    tail_k = property(lambda self: self._single().tail_k)
    tail_v = property(lambda self: self._single().tail_v)
    keep = property(lambda self: self._single().keep)

    @property
    def n_layers(self) -> int:
        return self.segments[-1].stop

    @property
    def keeps(self) -> tuple[int, ...]:
        """Per-layer kept corner sizes (the materialized plan)."""
        return tuple(s.keep for s in self.segments
                     for _ in range(s.stop - s.start))

    @property
    def codecs(self) -> tuple[str, ...]:
        """Per-layer codec family names (the materialized plan)."""
        return tuple(s.codec for s in self.segments
                     for _ in range(s.stop - s.start))

    @property
    def max_seq(self) -> int:
        return self.segments[0].packed_k.shape[2] * BLOCK

    def nbytes_per_token_per_layer(self) -> float:
        """Mean analytic compressed bytes per token per layer (K and V)."""
        total = 0.0
        for s in self.segments:
            _, _, _, hkv, nhd, k, _ = s.packed_k.shape
            total += (s.stop - s.start) * \
                block_group_bytes(k, hkv, nhd * BLOCK, codec=s.codec) / BLOCK
        return total / self.n_layers

    def storage_stats(self, raw_dtype_bytes: int = 2) -> dict:
        """Honest footprint of the pool vs a raw bf16 cache of equal shape."""
        seg = self.segments[0]
        _, b, ns, hkv, nhd, _, _ = seg.packed_k.shape
        hd = nhd * BLOCK
        kv_bytes = sum(s.nbytes() for s in self.segments)
        raw = self.n_layers * b * (ns * BLOCK) * hkv * hd * raw_dtype_bytes * 2
        return {
            "kv_bytes": kv_bytes,
            "raw_bytes": float(raw),
            "ratio": kv_bytes / raw,
            "keeps": self.keeps,
        }


def _segment_planes(pol, n_layers: int, prefix: tuple[int, ...], batch: int,
                    hkv: int, hd: int, dtype) -> dict[str, jax.Array]:
    """Zero planes for one segment from its family's declared plane tree.

    `prefix` is the cache layout's per-plane leading shape AFTER the layer
    axis and BEFORE the Hkv axis: (batch, S/8) dense, (n_pages,) paged.
    """
    fam = families_lib.get_family(pol.codec)
    planes: dict[str, jax.Array] = {}
    for spec in fam.plane_specs(pol.kv_keep, hd):
        shape = (n_layers,) + prefix + (hkv,) + spec.block_shape
        for sfx in ("_k", "_v"):
            planes[spec.name + sfx] = jnp.zeros(shape, spec.dtype)
    for name in TAIL_NAMES:
        planes[name] = jnp.zeros((n_layers, batch, BLOCK, hkv, hd), dtype)
    return planes


def init_compressed_cache(cfg, batch: int, max_seq: int, keep: int = 4,
                          dtype=jnp.bfloat16,
                          plan=None) -> CompressedKVCache:
    """Allocate the pool per `plan` (legacy scalar `keep` => uniform plan)."""
    assert max_seq % BLOCK == 0
    hd = cfg.resolved_head_dim
    assert hd % BLOCK == 0, f"head_dim {hd} not 8-tileable"
    plan = plan_lib.as_plan(plan, keep=keep)
    hkv = cfg.n_kv_heads
    ns = max_seq // BLOCK
    segments = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        planes = _segment_planes(pol, stop - start, (batch, ns), batch,
                                 hkv, hd, dtype)
        segments.append(KVSegment(planes, keep=pol.kv_keep, start=start,
                                  stop=stop, backend=pol.backend,
                                  codec=pol.codec))
    return CompressedKVCache(tuple(segments))


# ---------------------------------------------------------------------------
# Paged pool container (dynamic block-granular allocation)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
@dataclass
class PagedKVCache:
    """Shared page pool + per-slot block tables.

    `segments` are ordinary `KVSegment`s whose storage planes are PAGE
    pools instead of per-slot stores (tails stay per slot — an 8-token raw
    ring is not worth paging):

      packed_k/v : (Lseg, P, Hkv, hd/8, k, k) int8
      scale_k/v  : (Lseg, P, Hkv, hd/8)       f32
      tail_k/v   : (Lseg, B, 8, Hkv, hd)      raw dtype

    One page = one 8-token block group ACROSS all layers: every layer of a
    slot flushes the same block index at the same step, so page index p in
    segment arrays of every segment belongs to the same logical block.
    `block_table[b, j]` maps slot b's j-th sequence block to its page; the
    engine's host-side free list decides which page that is.  Unmapped
    entries hold 0 (a valid page, so gathers never go out of range) and are
    unreachable: attention masks `kv_pos < flushed` before any gather.
    """

    segments: tuple[KVSegment, ...]
    block_table: jax.Array  # (B, S/8) int32

    def tree_flatten(self):
        return (self.segments, self.block_table), ()

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return ((ga("segments"), self.segments),
                (ga("block_table"), self.block_table)), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tuple(children[0]), children[1])

    @property
    def n_layers(self) -> int:
        return self.segments[-1].stop

    @property
    def n_pages(self) -> int:
        return self.segments[0].packed_k.shape[1]

    @property
    def max_seq(self) -> int:
        return self.block_table.shape[1] * BLOCK

    @property
    def keeps(self) -> tuple[int, ...]:
        return tuple(s.keep for s in self.segments
                     for _ in range(s.stop - s.start))

    @property
    def codecs(self) -> tuple[str, ...]:
        return tuple(s.codec for s in self.segments
                     for _ in range(s.stop - s.start))

    def page_bytes(self) -> int:
        """Analytic bytes of one page across all layers (the allocation
        granule) — each segment charged by its own codec family."""
        total = 0
        for s in self.segments:
            _, _, hkv, nhd, k, _ = s.packed_k.shape
            total += (s.stop - s.start) * \
                block_group_bytes(k, hkv, nhd * BLOCK, codec=s.codec)
        return total


def init_paged_cache(cfg, batch: int, max_seq: int, n_pages: int,
                     keep: int = 4, dtype=jnp.bfloat16,
                     plan=None) -> PagedKVCache:
    """Allocate the shared page pool + block tables per `plan`.

    Same per-layer geometry as `init_compressed_cache`, but the block axis
    is a POOL of `n_pages` pages shared by every slot instead of a dense
    (B, max_seq/8) store — the feature-map buffer is sized by the traffic
    you want to hold, not by slots x worst-case depth.
    """
    assert max_seq % BLOCK == 0
    assert n_pages >= 1, n_pages
    hd = cfg.resolved_head_dim
    assert hd % BLOCK == 0, f"head_dim {hd} not 8-tileable"
    plan = plan_lib.as_plan(plan, keep=keep)
    hkv = cfg.n_kv_heads
    segments = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        planes = _segment_planes(pol, stop - start, (n_pages,), batch,
                                 hkv, hd, dtype)
        segments.append(KVSegment(planes, keep=pol.kv_keep, start=start,
                                  stop=stop, backend=pol.backend,
                                  codec=pol.codec))
    table = jnp.zeros((batch, max_seq // BLOCK), jnp.int32)
    return PagedKVCache(tuple(segments), table)


def measured_cache_bytes(cache) -> float:
    """MEASURED (data-dependent) compressed bytes resident in the cache —
    what the ROADMAP's "allocate pages by measured, not analytic, size"
    allocates against, reported beside the analytic worst case.

    Variable-length families (bitplane) carry a per-tile length plane
    (``blen``, in bits; written tiles are always > 0) — their measured
    bytes are the exact sum of stored stream bytes plus scale headers.
    Fixed-size families charge their analytic tile bytes per LIVE tile,
    where live is detected from nonzero carrier/scale content (an estimate:
    a flushed tile whose block quantized to all-zeros with zero scale is
    indistinguishable from an unwritten one).  Raw tails are charged at
    their full buffer size.  Host-side accounting — syncs the planes it
    inspects; call from stats paths, not the decode loop.
    """
    total = 0.0
    for seg in cache.segments:
        planes = seg.as_tree()
        fam = seg.family
        for name in TAIL_NAMES:
            a = planes[name]
            total += int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
        for sfx in ("_k", "_v"):
            if "blen" + sfx in planes:
                blen = np.asarray(planes["blen" + sfx])
                live = blen > 0
                header = families_lib.SCALE_HEADER_BYTES
                total += float(np.sum(
                    np.where(live, (blen + 7) // 8 + header, 0)))
            else:
                live = np.any(np.asarray(planes["packed" + sfx]) != 0,
                              axis=(-1, -2))
                if "scale" + sfx in planes:
                    live = live | (np.asarray(planes["scale" + sfx]) != 0)
                total += float(np.count_nonzero(live)) * \
                    fam.analytic_tile_bytes(seg.keep)
    return total


# ---------------------------------------------------------------------------
# Per-layer decode update (operates on the [B, ...] slices for one layer)
# ---------------------------------------------------------------------------

def update_layer(
    layer_cache: dict[str, jax.Array],
    k_new: jax.Array,  # (B, 1, Hkv, hd)
    v_new: jax.Array,
    pos: jax.Array,    # (B,) per-slot absolute positions (scalar broadcasts)
    keep: int,
    backend: str | None = None,
    *,
    flush_page: jax.Array | None = None,  # (B,) page ids (paged pool only)
    codec: str = "dct",
) -> dict[str, jax.Array]:
    """Write each row's new token into its own tail slot; flush per row.

    layer_cache holds the codec family's block planes in cache layout —
    ``{name}_k/v (B, S/8, Hkv) + block_shape`` (dct: packed_k/scale_k/
    packed_v/scale_v) — plus tail_k/tail_v (B, 8, Hkv, hd).

    Every row carries its own position, so the tail write is a batched
    scatter at slot = pos % 8, and the block flush is a masked scatter at
    blk = pos // 8 that only lands for rows whose tail just filled (rows
    that don't flush scatter to an out-of-range index and are dropped).
    A single global cond skips the codec entirely on steps where NO row
    flushes (7 of 8 steps in lock-step serving) — the per-row decision
    stays a masked scatter either way.

    PAGED pool: pass `flush_page` and pool-shaped block planes
    ((P, Hkv) + block_shape).  The flush then scatters row b's block into
    page `flush_page[b]` instead of (b, pos//8); the engine hands out page
    ids (its free list is the allocator) and sets out-of-range ids (>= P)
    for rows that must not flush, which the drop-mode scatter discards.
    The caller owns the block-table update — this function never sees the
    table.
    """
    fam = families_lib.get_family(codec)
    b = k_new.shape[0]
    pos = as_pos_vec(pos, b)
    rows = jnp.arange(b)
    slot = jnp.mod(pos, BLOCK)
    # per-row scatters: the row index IS the batch index, so under a
    # slot-sharded pool (data axes on B) every write lands on the shard that
    # owns the slot — constrain the results so GSPMD keeps it that way
    # instead of round-tripping the tail ring through a gather.
    tail_k = layer_cache["tail_k"].at[rows, slot].set(
        k_new[:, 0].astype(layer_cache["tail_k"].dtype)
    )
    tail_v = layer_cache["tail_v"].at[rows, slot].set(
        v_new[:, 0].astype(layer_cache["tail_v"].dtype)
    )
    tail_k = shard_hint(tail_k, "batch", None, "model", None)
    tail_v = shard_hint(tail_v, "batch", None, "model", None)

    paged = flush_page is not None
    block_names = tuple(sorted(n for n in layer_cache if n not in TAIL_NAMES))
    blocks = {n: layer_cache[n] for n in block_names}
    ns = layer_cache["packed_k"].shape[1]  # dense: S/8 blocks; paged: Hkv

    flush_row = slot == BLOCK - 1

    def flush(args):
        blocks, tk, tv = args
        # (B, 8, Hkv, hd) -> (B, Hkv, 8, hd) planes -> one block per row
        qk, sck = compress_kv_blocks(jnp.swapaxes(tk, 1, 2), keep, backend)
        qv, scv = compress_kv_blocks(jnp.swapaxes(tv, 1, 2), keep, backend)
        # qk: (B, Hkv, 1, hd/8, k, k) -> cache layout (B, Hkv, hd/8, k, k);
        # the family lays the quantized blocks out into its declared planes
        upd = {}
        for sfx, q, sc in (("_k", qk, sck), ("_v", qv, scv)):
            q = jnp.swapaxes(q, 1, 2)[:, 0]
            sc = jnp.swapaxes(sc, 1, 2)[:, 0]
            for name, plane in fam.pack(q, sc, keep).items():
                upd[name + sfx] = plane
        if paged:
            # guard against stray ids on non-flushing rows: force them out
            # of range so the drop-mode scatter discards them
            page = jnp.where(flush_row, flush_page,
                             blocks["packed_k"].shape[0])
            return {n: blocks[n].at[page].set(
                upd[n].astype(blocks[n].dtype), mode="drop")
                for n in block_names}
        blk = jnp.where(flush_row, pos // BLOCK, ns)  # ns => dropped
        return {n: blocks[n].at[rows, blk].set(
            upd[n].astype(blocks[n].dtype), mode="drop")
            for n in block_names}

    def no_flush(args):
        blocks, _, _ = args
        return dict(blocks)

    blocks = jax.lax.cond(jnp.any(flush_row), flush, no_flush,
                          (blocks, tail_k, tail_v))
    if paged:
        # pool layout per cache_specs: pages ride the data axes (the batch
        # scatter above crosses banks by design — the page allocator does
        # not know about devices), heads on `model` when they divide it
        blocks = {n: shard_hint(a, "batch", "model", *[None] * (a.ndim - 2))
                  for n, a in blocks.items()}
    else:
        # block-plane layout must MATCH cache_specs: heads on `model` when
        # they divide it, else the S/8 block axis (attn_hint implements that
        # fallback) — a plain heads-only hint would conflict with the pool
        # specs for non-dividing head counts and force a full-store reshard
        # per step
        blocks = {n: attn_hint(a, s_axis=1, h_axis=2)
                  for n, a in blocks.items()}
    return dict(blocks, tail_k=tail_k, tail_v=tail_v)


# ---------------------------------------------------------------------------
# Flash attention over the compressed store (decode: Sq == 1)
# ---------------------------------------------------------------------------

def _repeat_heads(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, Hkv, S, hd) -> (B, Hkv*n_rep, S, hd)."""
    if n_rep == 1:
        return x
    b, hkv, s, hd = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, hkv, n_rep, s, hd)).reshape(b, hkv * n_rep, s, hd)


def attend_compressed(
    q: jax.Array,                 # (B, 1, H, hd)
    layer_cache: dict[str, jax.Array],
    pos: jax.Array,               # (B,) per-slot positions (scalar broadcasts)
    keep: int,
    *,
    kv_block: int = 1024,
    scale: float | None = None,
    backend: str | None = None,
    block_table: jax.Array | None = None,  # (B, S/8) page ids (paged pool)
    codec: str = "dct",
) -> jax.Array:
    """Online-softmax decode attention where K/V history is decompressed per
    chunk INSIDE the scan — compressed bytes are what stream from HBM.

    Each row attends under its OWN causal horizon: packed blocks below that
    row's flushed watermark, plus its raw tail (positions pos-pos%8 .. pos)
    merged with the same running-max algebra.

    The codec family unpacks its declared planes back to quantized blocks
    per chunk (for dct that unpack is the identity, so the op stream is
    bit-for-bit the pre-family path).  With `block_table`, the block planes
    are the shared PAGE POOL ((P, Hkv) + block_shape) and each chunk
    gathers its blocks through the table first.  Chunk boundaries and every
    float op after the gather are identical to the dense layout, so greedy
    decode over a paged pool is bitwise the dense result (tests pin this).
    """
    fam = families_lib.get_family(codec)
    bases = tuple(sorted({n[:-2] for n in layer_cache if n not in TAIL_NAMES}))
    b, sq, h, hd = q.shape
    pos = as_pos_vec(pos, b)
    pk = layer_cache["packed_k"]
    if block_table is None:
        _, nblocks_total, hkv, nhd, k, _ = pk.shape
    else:
        _, hkv, nhd, k, _ = pk.shape
        nblocks_total = block_table.shape[1]
    n_rep = h // hkv
    max_seq = nblocks_total * BLOCK
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kv_block = min(kv_block, max_seq)
    while max_seq % kv_block:  # shrink to a divisor (max_seq is a mult of 8)
        kv_block -= BLOCK
    assert kv_block % BLOCK == 0 and kv_block > 0
    bpc = kv_block // BLOCK
    nchunks = max_seq // kv_block

    qf = (q.astype(jnp.float32) * scale)[:, 0]           # (B, H, hd)
    flushed = (pos // BLOCK) * BLOCK                      # (B,) packed watermark

    def chunk_body(carry, c):
        m, l, acc = carry
        start = c * bpc
        if block_table is None:
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, start, bpc, 1)
        else:
            # gather this chunk's pages: (B, bpc) table slice -> pool rows.
            # Unmapped entries point at page 0 — valid, and masked below.
            pages = jax.lax.dynamic_slice_in_dim(block_table, start, bpc, 1)
            sl = lambda a: a[pages]                       # (B, bpc, Hkv, ...)

        def chunk_planes(sfx):
            # planes per (B, Hkv): (B, nb, Hkv, ...) -> (B, Hkv, nb, ...)
            return {base: jnp.swapaxes(sl(layer_cache[base + sfx]), 1, 2)
                    for base in bases}

        kq, ksc = fam.unpack(chunk_planes("_k"), k)
        vq, vsc = fam.unpack(chunk_planes("_v"), k)
        kc = decompress_kv_blocks(kq, ksc, jnp.float32, backend)
        vc = decompress_kv_blocks(vq, vsc, jnp.float32, backend)
        # kc/vc: (B, Hkv, kv_block, hd)
        kc = attn_hint(kc, s_axis=2, h_axis=1)  # heads else kv_block on model
        vc = attn_hint(vc, s_axis=2, h_axis=1)
        kr = _repeat_heads(kc, n_rep)                     # (B, H, kv_block, hd)
        vr = _repeat_heads(vc, n_rep)
        kv_pos = start * BLOCK + jnp.arange(kv_block)
        valid = kv_pos[None] < flushed[:, None]           # (B, kv_block) per row
        s = jnp.einsum("bhd,bhkd->bhk", qf, kr)
        s = jnp.where(valid[:, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid[:, None], jnp.exp(s - m_safe[..., None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhk,bhkd->bhd", p, vr)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    acc0 = jnp.zeros((b, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk_body, (m0, l0, acc0), jnp.arange(nchunks))

    # ---- raw tail: positions flushed .. pos (inclusive) -------------------
    tk = jnp.swapaxes(layer_cache["tail_k"], 1, 2).astype(jnp.float32)  # (B,Hkv,8,hd)
    tv = jnp.swapaxes(layer_cache["tail_v"], 1, 2).astype(jnp.float32)
    tkr = _repeat_heads(tk, n_rep)
    tvr = _repeat_heads(tv, n_rep)
    tail_pos = flushed[:, None] + jnp.arange(BLOCK)       # (B, 8)
    tvalid = tail_pos <= pos[:, None]
    st = jnp.einsum("bhd,bhkd->bhk", qf, tkr)
    st = jnp.where(tvalid[:, None], st, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(st, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    pt = jnp.where(tvalid[:, None], jnp.exp(st - m_safe[..., None]), 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l = l * alpha + jnp.sum(pt, axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum("bhk,bhkd->bhd", pt, tvr)

    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B, H, hd)
    out = shard_hint(out, "batch", "model", None)
    return out[:, None].astype(q.dtype)           # (B, 1, H, hd)


def table_view(block_table: jax.Array,
               attend_blocks: int | None = None) -> jax.Array:
    """Static bucket slice of a block table: its first `attend_blocks`
    entries (None / >= table width => the full table).

    The decode-bucket ladder picks `attend_blocks` to cover the deepest
    live slot's flushed watermark, so every trailing entry this view drops
    can only name blocks the attend masks anyway — the slice is an exact
    no-op on the attention output.  What it changes is cost: the reference
    scan's chunk gather and the paged kernel's grid cover only the sliced
    width, so decode-step work tracks occupied context, not pool capacity.
    """
    nb = block_table.shape[1]
    if attend_blocks is None or attend_blocks >= nb:
        return block_table
    assert attend_blocks >= 1, attend_blocks
    return table_slice_hint(block_table[:, :attend_blocks])


def attend_auto(
    q: jax.Array,
    layer_cache: dict[str, jax.Array],
    pos: jax.Array,               # (B,) per-slot positions (scalar broadcasts)
    keep: int,
    *,
    kv_block: int = 1024,
    backend: str | None = None,
    block_table: jax.Array | None = None,  # (B, nblocks) page ids (paged)
    pages_per_tile: int = 8,
    codec: str = "dct",
) -> jax.Array:
    """Backend-dispatched decode attention over the compressed store.

    `pallas` routes to the fused decompress+attend kernel (int8 blocks are
    what stream from HBM; the IDCT runs in VMEM); `reference` (and any other
    backend) uses the pure-JAX online-softmax scan above. Selection follows
    repro.codec.dispatch, same as the block codec itself. Both backends take
    the per-slot position vector, and both gather paged history through
    `block_table` when given one — possibly a `table_view` bucket slice —
    (the kernel reads the table on the scalar-prefetch path beside `pos`;
    `pages_per_tile` is the kernel's G-page tile width).

    Only the dct family's plane layout matches what the fused kernel reads
    (`CodecFamily.supports_fused_attend`); other families always decode
    through the reference scan, whatever the backend says.
    """
    pos = as_pos_vec(pos, q.shape[0])
    fused_ok = families_lib.get_family(codec).supports_fused_attend
    if fused_ok and codec_lib.resolve_backend_name(backend) == "pallas":
        from repro.kernels.fused_attend import ops as fa_ops

        return fa_ops.attend_with_tail(q, layer_cache, pos, tile_s=kv_block,
                                       block_table=block_table,
                                       pages_per_tile=pages_per_tile)
    return attend_compressed(q, layer_cache, pos, keep, kv_block=kv_block,
                             backend=backend, block_table=block_table,
                             codec=codec)


# ---------------------------------------------------------------------------
# Bulk prefill: compress a whole prompt's K/V at once
# ---------------------------------------------------------------------------

def prefill_compress(
    k: jax.Array,  # (B, S, Hkv, hd), S % 8 == 0
    v: jax.Array,
    keep: int,
    pos: jax.Array | None = None,  # (B,) per-row prompt lengths; None => S
    backend: str | None = None,
    codec: str = "dct",
) -> dict[str, jax.Array]:
    """Compress a full prompt's K/V for one layer into cache layout.

    `pos[b]` is row b's prompt length (= its next decode position).  All
    blocks are compressed unconditionally — blocks at or above a row's
    flushed watermark (pos//8 * 8) hold padding garbage, but attention masks
    them (`kv_pos < flushed`) and the decode flush overwrites each one
    before it ever becomes visible.  The trailing partial block of each row
    (positions flushed .. flushed+7) is returned raw as tail_k/tail_v, per
    row, ready to drop into the cache's tail ring.

    Invariant: tail entries at indices >= pos%8 are clamped-gather garbage
    that `tvalid = tail_pos <= pos` treats as valid at position pos itself.
    Decode must therefore WRITE position pos (update_layer) before attending
    at pos — which is exactly what decode_step_compressed does; the first
    post-prefill token is sampled from the prefill logits, never attended
    out of this cache.
    """
    fam = families_lib.get_family(codec)
    b, s = k.shape[:2]
    pos = as_pos_vec(s if pos is None else pos, b)
    kq, ks = compress_kv_blocks(jnp.swapaxes(k, 1, 2), keep, backend)  # (B,Hkv,S/8,hd/8,k,k)
    vq, vs = compress_kv_blocks(jnp.swapaxes(v, 1, 2), keep, backend)
    # per-row raw tail: gather rows flushed .. flushed+7 (clamped; rows past
    # the prompt are masked at attend time by tail_pos <= pos)
    idx = (pos[:, None] // BLOCK) * BLOCK + jnp.arange(BLOCK)  # (B, 8)
    idx = jnp.minimum(idx, s - 1)[:, :, None, None]
    tail_k = jnp.take_along_axis(k, idx, axis=1)               # (B, 8, Hkv, hd)
    tail_v = jnp.take_along_axis(v, idx, axis=1)
    out = dict(tail_k=tail_k, tail_v=tail_v)
    for sfx, q, sc in (("_k", kq, ks), ("_v", vq, vs)):
        for name, plane in fam.pack(q, sc, keep).items():
            out[name + sfx] = jnp.swapaxes(plane, 1, 2)  # -> (B, S/8, Hkv, ...)
    return out


# ---------------------------------------------------------------------------
# Slot lifecycle (continuous batching)
# ---------------------------------------------------------------------------

def cache_reset_slot(cache, slot: jax.Array | int):
    """Zero one batch slot's planes — axis 1 of every leaf (retirement).

    Works on any cache pytree with the (L, B, ...) layout: the
    CompressedKVCache (packed/scale/tail planes; `keep` rides as aux data)
    and the raw k/v and MLA latent dicts alike. Freshly-admitted requests
    overwrite the slot wholesale at prefill, so this is belt-and-braces
    hygiene — but it keeps retired garbage out of storage-stats scans and
    makes slot reuse auditable in tests.
    """
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree.map(lambda a: a.at[:, slot].set(jnp.zeros_like(a[:, 0])), cache)


def paged_write_slot(cache: PagedKVCache, slot_update, slot: jax.Array,
                     page_ids: jax.Array, table_row: jax.Array) -> PagedKVCache:
    """Splice one admitted request into the paged pool.

    `slot_update` is the per-segment tuple of dicts a paged prefill returns:
    packed/scale planes hold the prompt's OWN blocks only
    ((Lseg, 1, nb, ...) with nb = bucket/8 — never max_seq/8), tails are the
    (Lseg, 1, 8, Hkv, hd) raw remainder.  `page_ids` (nb,) carries the
    engine-assigned page per prompt block, padded with out-of-range ids
    (>= P) past the prompt's full blocks so the drop-mode scatter ignores
    the padding blocks; `table_row` (S/8,) is the slot's new block-table
    row (assigned pages then zeros).  Admission therefore writes O(prompt)
    pool bytes plus one table row — nothing max_seq-sized is zero-filled.
    """
    slot = jnp.asarray(slot, jnp.int32)
    segments = []
    for seg, upd in zip(cache.segments, slot_update):
        planes = seg.as_tree()
        new = {}
        for key in seg.page_keys:
            new[key] = planes[key].at[:, page_ids].set(
                upd[key][:, 0].astype(planes[key].dtype), mode="drop")
        for key in TAIL_NAMES:
            new[key] = jax.lax.dynamic_update_slice_in_dim(
                planes[key], upd[key].astype(planes[key].dtype), slot, axis=1)
        segments.append(seg.replace_arrays(new))
    table = cache.block_table.at[slot].set(table_row)
    return PagedKVCache(tuple(segments), table)


def paged_write_rows(cache: PagedKVCache, rows_update, slots: jax.Array,
                     page_ids: jax.Array, table_rows: jax.Array) -> PagedKVCache:
    """Splice a PACKED admission (R requests in one bucketed prefill) into
    the paged pool — the batched `paged_write_slot`.

    `rows_update` is the per-segment tuple of dicts a paged prefill returns
    with R rows: packed/scale planes (Lseg, R, nb, ...), tails
    (Lseg, R, 8, Hkv, hd).  `slots` (R,) assigns row r to pool slot
    slots[r]; `page_ids` (R, nb) carries each row's engine-assigned page per
    prompt block; `table_rows` (R, S/8) the new block-table rows.  Rows the
    admission group padded to a warmed row count carry out-of-range slot
    ids (>= B) and all-out-of-range page ids, so every one of their writes
    drops — a padding row can land nowhere.
    """
    slots = jnp.asarray(slots, jnp.int32)
    segments = []
    for seg, upd in zip(cache.segments, rows_update):
        planes = seg.as_tree()
        new = {}
        for key in seg.page_keys:
            # planes[key]: (Lseg, P, ...); page_ids (R, nb) gathers to
            # (Lseg, R, nb, ...) — exactly upd[key]'s shape
            new[key] = planes[key].at[:, page_ids].set(
                upd[key].astype(planes[key].dtype), mode="drop")
        for key in TAIL_NAMES:
            new[key] = planes[key].at[:, slots].set(
                upd[key].astype(planes[key].dtype), mode="drop")
        segments.append(seg.replace_arrays(new))
    table = cache.block_table.at[slots].set(table_rows, mode="drop")
    return PagedKVCache(tuple(segments), table)


def paged_gather_slot(cache: PagedKVCache, slot: jax.Array,
                      page_ids: jax.Array):
    """Read pages (+ one slot's tail) OUT of the pool — the gather half of
    the tier path, exact inverse of `paged_write_slot`.

    Returns the per-segment tuple of dicts `paged_write_slot` accepts:
    packed/scale planes (Lseg, 1, nb, ...) gathered at `page_ids` (nb,),
    tails (Lseg, 1, 8, Hkv, hd) sliced at `slot`. Out-of-range page ids
    clamp to the last page — callers pad the page vector to a warmed bucket
    width and ignore the padding entries, mirroring the drop-mode scatter
    on the write side. The engine's TierManager numpy-ifies the result into
    host pages; feeding it back through `paged_write_slot` at fresh page
    ids is a bitwise round trip (int8/f32/raw-tail planes copy exactly).

    Tier semantics: which of a slot's logical blocks are device- vs
    host-resident is HOST state (the engine's per-slot page lists and
    parked records) — the device block table only ever holds device page
    ids, and a parked slot's row is zeroed until its restore rebuilds it.
    """
    slot = jnp.asarray(slot, jnp.int32)
    out = []
    for seg in cache.segments:
        planes = seg.as_tree()
        ids = jnp.minimum(page_ids, planes["packed_k"].shape[1] - 1)
        upd = {}
        for key in seg.page_keys:
            upd[key] = planes[key][:, ids][:, None]  # (Lseg, 1, nb, ...)
        for key in TAIL_NAMES:
            upd[key] = jax.lax.dynamic_slice_in_dim(planes[key], slot, 1,
                                                    axis=1)
        out.append(upd)
    return out


def paged_rows_match(cache: PagedKVCache, rows_update, page_ids: jax.Array):
    """Bitwise-compare pool pages against admission update rows.

    `rows_update` is the (Lseg, R, nb, ...) tree a packed paged prefill
    returns; `page_ids` (R, nb) names the candidate page per (row, block).
    Returns an (R, nb) bool: True iff every packed int8 element AND every
    f32 scale of the candidate page equals the row's freshly computed
    block — the copy-on-write sharing verifier (hash-equal prefixes are
    only shared once this says their pages are bitwise equal). Out-of-range
    ids clamp; callers mask non-candidate entries host-side.
    """
    ok = jnp.ones(page_ids.shape, bool)
    for seg, upd in zip(cache.segments, rows_update):
        planes = seg.as_tree()
        ids = jnp.minimum(page_ids, planes["packed_k"].shape[1] - 1)
        for key in seg.page_keys:
            got = planes[key][:, ids]  # (Lseg, R, nb, ...)
            want = upd[key].astype(planes[key].dtype)
            eq = got == want
            axes = tuple(a for a in range(eq.ndim) if a not in (1, 2))
            ok = ok & jnp.all(eq, axis=axes)
    return ok


def paged_reset_slot(cache: PagedKVCache, slot: jax.Array) -> PagedKVCache:
    """Retire one slot: zero its tails and block-table row.

    Page CONTENTS are not touched — the engine's free list reclaims the
    page ids, and a page is unreachable the moment no table row maps it
    (the device-side analogue of free()).  Zeroing the table row keeps
    retired mappings out of gathers and makes reuse auditable in tests.
    """
    slot = jnp.asarray(slot, jnp.int32)
    segments = []
    for seg in cache.segments:
        planes = seg.as_tree()
        new = dict(planes)
        for key in TAIL_NAMES:
            new[key] = planes[key].at[:, slot].set(
                jnp.zeros_like(planes[key][:, 0]))
        segments.append(seg.replace_arrays(new))
    table = cache.block_table.at[slot].set(
        jnp.zeros_like(cache.block_table[0]))
    return PagedKVCache(tuple(segments), table)
