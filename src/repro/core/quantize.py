"""Two-step quantization of DCT coefficient blocks (paper Eq. 7-10).

Step 1 ("low-precision GEMM"): affine min-max quantization of the whole
coefficient tensor to m-bit unsigned integers (Eq. 7).
Step 2 ("Q-table quantization"): element-wise division by a JPEG-style 8x8
table (Eq. 8).  Four quantization levels (a 2-bit register in the paper) scale
the table; early layers use aggressive tables, deep layers gentle ones.

Inverse quantization is Eq. 9-10.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

# The JPEG luminance quantization table (Annex K of the JPEG standard) — the
# paper says "We refer to the JPEG Q-table which has small values in the top
# left ... and large values in the bottom right".
JPEG_LUMA_QTABLE = np.array(
    [
        [16, 11, 10, 16, 24, 40, 51, 61],
        [12, 12, 14, 19, 26, 58, 60, 55],
        [14, 13, 16, 24, 40, 57, 69, 56],
        [14, 17, 22, 29, 51, 87, 80, 62],
        [18, 22, 37, 56, 68, 109, 103, 77],
        [24, 35, 55, 64, 81, 104, 113, 92],
        [49, 64, 78, 87, 103, 121, 120, 101],
        [72, 92, 95, 98, 112, 100, 103, 99],
    ],
    dtype=np.float64,
)

# Four quantization levels (the paper's 2-bit register).  Level 0 is the most
# aggressive (first few layers: "Q-table values ... larger in order to get a
# better compression ratio"), level 3 the gentlest (deep layers: "adjusted to
# smaller values to ensure the accuracy of the network").  The scale follows
# the JPEG quality-factor convention.
QUALITY_PER_LEVEL = (25, 50, 75, 92)


@functools.lru_cache(maxsize=None)
def qtable_for_level(level: int) -> np.ndarray:
    """JPEG quality-factor scaling of the base table (libjpeg convention)."""
    q = QUALITY_PER_LEVEL[level]
    scale = 5000.0 / q if q < 50 else 200.0 - 2.0 * q
    t = np.floor((JPEG_LUMA_QTABLE * scale + 50.0) / 100.0)
    return np.clip(t, 1.0, 255.0)


def qtable(level: int, dtype=jnp.float32) -> jax.Array:
    return jnp.asarray(qtable_for_level(level), dtype=dtype)


def qtable_plane(level: int, r: int, c: int, dtype=jnp.float32) -> jax.Array:
    """The 8x8 Q-table tiled to an aligned (r, c) coefficient plane."""
    return jnp.tile(qtable(level, dtype), (r // 8, c // 8))


@dataclass(frozen=True)
class QuantParams:
    """Per-tensor affine range for step 1 (Eq. 7).

    `zero_point` is the integer code of the real value 0.  The paper's Eq. 7/8
    as literally written divide the *unsigned* code by the Q-table, which would
    discard Fmin-adjacent (not zero-adjacent) coefficients and destroy the
    signal; the claimed outcome ("large number of zeros in the bottom right
    corner") requires the JPEG-style level shift, so Q-table quantization is
    applied to the zero-centred code (see DESIGN.md §6).
    """

    fmin: jax.Array  # scalar (or broadcastable per-channel) minimum
    fmax: jax.Array
    bits: int = 8

    @property
    def imax(self) -> int:
        return (1 << self.bits) - 1

    @property
    def zero_point(self) -> jax.Array:
        scale = self.imax / (self.fmax - self.fmin)
        return jnp.round(jnp.clip(-self.fmin * scale, 0, self.imax))


def compute_range(coefs: jax.Array, axis=None) -> tuple[jax.Array, jax.Array]:
    fmin = jnp.min(coefs, axis=axis, keepdims=axis is not None)
    fmax = jnp.max(coefs, axis=axis, keepdims=axis is not None)
    # Guard degenerate range (constant tensor) to keep Eq. 7 well defined.
    fmax = jnp.where(fmax - fmin < 1e-12, fmin + 1.0, fmax)
    return fmin, fmax


def quantize_minmax(coefs: jax.Array, params: QuantParams) -> jax.Array:
    """Eq. 7: Q1 = round((F - Fmin) / (Fmax - Fmin) * imax). Unsigned m-bit."""
    scale = params.imax / (params.fmax - params.fmin)
    q1 = jnp.round((coefs - params.fmin) * scale)
    return jnp.clip(q1, 0, params.imax)


def quantize_qtable(q1: jax.Array, level: int, zero_point=0.0) -> jax.Array:
    """Eq. 8 with level shift: Q2 = round((Q1 - zp) / QT) over (8, 8) blocks."""
    qt = qtable(level, q1.dtype)
    return jnp.round((q1 - zero_point) / qt)


def dequantize_qtable(q2: jax.Array, level: int, zero_point=0.0) -> jax.Array:
    """Eq. 9 with level shift: Q1' = Q2 * QT + zp."""
    return q2 * qtable(level, q2.dtype) + zero_point


def dequantize_minmax(q1: jax.Array, params: QuantParams) -> jax.Array:
    """Eq. 10: F' = Q1'/imax * (Fmax - Fmin) + Fmin."""
    return q1 / params.imax * (params.fmax - params.fmin) + params.fmin


def quantize_blocks(
    coefs: jax.Array, level: int, bits: int = 8
) -> tuple[jax.Array, QuantParams]:
    """Full two-step quantization of (..., 8, 8) DCT coefficient blocks."""
    fmin, fmax = compute_range(coefs)
    params = QuantParams(fmin=fmin, fmax=fmax, bits=bits)
    q1 = quantize_minmax(coefs, params)
    q2 = quantize_qtable(q1, level, params.zero_point)
    return q2, params


def dequantize_blocks(q2: jax.Array, params: QuantParams, level: int) -> jax.Array:
    q1 = dequantize_qtable(q2, level, params.zero_point)
    return dequantize_minmax(q1, params)


# ---------------------------------------------------------------------------
# Structured frequency truncation (TPU runtime path — DESIGN.md §2).
# Keep the top-left k x k low-frequency corner of each 8x8 block as dense int8.
# ---------------------------------------------------------------------------

def truncation_mask(k: int, block: int = 8, dtype=jnp.float32) -> jax.Array:
    """1 on the top-left k x k corner, 0 elsewhere."""
    idx = jnp.arange(block)
    return ((idx[:, None] < k) & (idx[None, :] < k)).astype(dtype)


def level_to_keep(level: int) -> int:
    """Map the paper's 4 quantization levels to a kept corner size k.

    Chosen so the zero pattern matches the Q-table zero statistics measured on
    1/f inputs (benchmarks/codec_compare.py): aggressive level 0 keeps 2x2,
    gentle level 3 keeps 6x6.
    """
    return (2, 3, 4, 6)[level]
