"""Deterministic synthetic data pipeline.

No datasets ship in this container, so the framework generates its own:

* `natural_images` — 1/f^alpha power-spectrum RGB images. Natural images have
  ~1/f^2 power spectra; this is the statistic that makes the paper's DCT
  compression work on early-layer feature maps, so it is the right null model
  for reproducing Table III compression ratios without PASCAL VOC.
* `shapes_dataset` — procedural 4-class shape classification (circle, square,
  triangle, cross) for the trained accuracy-loss experiment.
* `TokenStream` — deterministic, host-shardable LM token batches with a
  Zipfian unigram mixed with structured n-gram correlations (so losses and
  activations are not degenerate white noise).

Everything is seeded and indexable by (step, host) so that elastic restarts
replay exactly (runtime/fault.py relies on this).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def natural_images(seed: int, batch: int, h: int, w: int, c: int = 3, alpha: float = 2.0) -> np.ndarray:
    """1/f^alpha images, unit variance per channel, NHWC float32."""
    rng = np.random.default_rng(seed)
    fy = np.fft.fftfreq(h)[:, None]
    fx = np.fft.fftfreq(w)[None, :]
    f = np.sqrt(fy**2 + fx**2)
    f[0, 0] = 1.0
    filt = 1.0 / f ** (alpha / 2.0)
    spec = rng.standard_normal((batch, c, h, w)) + 1j * rng.standard_normal((batch, c, h, w))
    img = np.fft.ifft2(spec * filt, axes=(-2, -1)).real
    img -= img.mean(axis=(-2, -1), keepdims=True)
    img /= img.std(axis=(-2, -1), keepdims=True) + 1e-9
    return np.transpose(img, (0, 2, 3, 1)).astype(np.float32)


def shapes_dataset(seed: int, n: int, size: int = 32) -> tuple[np.ndarray, np.ndarray]:
    """Procedural shapes: returns (images NHWC (n,size,size,1), labels (n,))."""
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, size, size, 1), np.float32)
    labels = rng.integers(0, 4, n)
    yy, xx = np.mgrid[0:size, 0:size]
    for i in range(n):
        cx, cy = rng.uniform(size * 0.3, size * 0.7, 2)
        r = rng.uniform(size * 0.15, size * 0.3)
        lab = labels[i]
        if lab == 0:  # circle
            m = (xx - cx) ** 2 + (yy - cy) ** 2 < r**2
        elif lab == 1:  # square
            m = (np.abs(xx - cx) < r) & (np.abs(yy - cy) < r)
        elif lab == 2:  # triangle
            m = (yy - cy > -r) & (np.abs(xx - cx) < (yy - cy + r) * 0.6) & (yy - cy < r)
        else:  # cross
            m = (np.abs(xx - cx) < r * 0.35) | (np.abs(yy - cy) < r * 0.35)
            m &= (np.abs(xx - cx) < r) & (np.abs(yy - cy) < r)
        img = m.astype(np.float32)
        img += rng.normal(0, 0.15, img.shape)  # sensor noise
        imgs[i, :, :, 0] = img
    return imgs, labels.astype(np.int32)


@dataclass(frozen=True)
class TokenStream:
    """Deterministic sharded LM token stream.

    batch(step, shard, num_shards) is a pure function of its arguments — any
    host can regenerate any shard at any step, which is what makes elastic
    restart with a different data-parallel size exact (DESIGN.md FT section).
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict[str, np.ndarray]:
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard, num_shards])
        )
        # Zipfian unigrams with injected repeated motifs (n-gram structure)
        z = rng.zipf(1.3, size=(b, self.seq_len)).astype(np.int64)
        tokens = (z - 1) % self.vocab_size
        # motif injection: copy short spans forward to create learnable bigrams
        for row in range(b):
            for _ in range(self.seq_len // 64):
                src = rng.integers(0, self.seq_len - 16)
                dst = rng.integers(0, self.seq_len - 16)
                tokens[row, dst : dst + 8] = tokens[row, src : src + 8]
        inputs = tokens[:, :-1]
        labels = tokens[:, 1:]
        return {
            "tokens": np.pad(inputs, ((0, 0), (0, 1))).astype(np.int32),
            "labels": np.pad(labels, ((0, 0), (0, 1)), constant_values=-1).astype(np.int32),
        }
