"""dct8x8 kernel package."""
from repro.kernels.dct8x8 import kernel, ops, ref
