"""dct8x8 kernel package (dispatch lives in repro.codec; ops.py shim removed)."""
from repro.kernels.dct8x8 import kernel, ref
