"""Pallas TPU kernel: blocked 8x8 2-D DCT/IDCT over a plane.

TPU mapping (DESIGN.md §2): transforming every aligned 8x8 block of a (R, C)
plane is expressed as two *dense* matmuls with block-diagonal constants,

    Z = kron(I_{TR/8}, C) @ X @ kron(I_{TC/8}, C)^T

so the kernel is two MXU matmuls per tile — no transposes, no gathers, the
constant operand stays resident in VMEM across the whole grid.  The MXU is a
fixed-function 128x128 systolic array: a block-diagonal 128x128 operand runs at
the same rate as a dense one, so this formulation is time-optimal on TPU even
though 7/8 of the multiplier lanes carry zeros (the paper's CCM array makes the
same trade the other way: constant-coefficient multipliers with zero-gating).

Grid: (R/TR, C/TC).  VMEM per step: TR*TC*(2 tiles) + TR^2 + TC^2 floats —
TR=TC=128 => ~200 KB of f32, comfortably inside the ~16 MB VMEM budget, with
room for double-buffered pipelining by the Pallas runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.dct import _dct_matrix_np

BLOCK = 8


@functools.lru_cache(maxsize=None)
def block_diag_dct_np(size: int) -> np.ndarray:
    """kron(I_{size/8}, C8) as float32 — the per-tile constant operand."""
    assert size % BLOCK == 0
    c = _dct_matrix_np(BLOCK).astype(np.float32)
    return np.kron(np.eye(size // BLOCK, dtype=np.float32), c)


def _dct_tile_kernel(x_ref, bdr_ref, bdc_ref, o_ref, *, inverse: bool):
    x = x_ref[...].astype(jnp.float32)
    bdr = bdr_ref[...]
    bdc = bdc_ref[...]
    if inverse:
        # X = BDr^T Z BDc  (Eq. 6 lifted to the block-diagonal form)
        y = jax.lax.dot(bdr.T, x, preferred_element_type=jnp.float32)
        y = jax.lax.dot(y, bdc, preferred_element_type=jnp.float32)
    else:
        # Z = BDr X BDc^T  (Eq. 5)
        y = jax.lax.dot(bdr, x, preferred_element_type=jnp.float32)
        y = jax.lax.dot(y, bdc.T, preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def dct2_plane_pallas(
    x: jax.Array,
    *,
    inverse: bool = False,
    tile_r: int = 128,
    tile_c: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked 2-D DCT of a (R, C) plane; R, C multiples of 8.

    Pads to tile multiples (zero padding only ever adds whole 8x8 blocks whose
    coefficients are sliced off again), runs the tiled Pallas kernel.
    """
    r, c = x.shape
    assert r % BLOCK == 0 and c % BLOCK == 0, (r, c)
    tr = min(tile_r, r)
    tc = min(tile_c, c)
    pr = (-r) % tr
    pc = (-c) % tc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    rp, cp = xp.shape

    bdr = jnp.asarray(block_diag_dct_np(tr))
    bdc = jnp.asarray(block_diag_dct_np(tc))

    out = pl.pallas_call(
        functools.partial(_dct_tile_kernel, inverse=inverse),
        grid=(rp // tr, cp // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tr), lambda i, j: (0, 0)),
            pl.BlockSpec((tc, tc), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), x.dtype),
        interpret=interpret,
    )(xp, bdr, bdc)
    return out[:r, :c]
