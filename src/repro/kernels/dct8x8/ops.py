"""Jitted public wrappers for the dct8x8 Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dct8x8 import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("inverse", "interpret"))
def dct2(x: jax.Array, inverse: bool = False, interpret: bool | None = None) -> jax.Array:
    """Blocked 8x8 2-D DCT (or IDCT) of a plane, any leading batch dims.

    interpret=None auto-selects: compiled on TPU, interpret elsewhere (CPU CI).
    """
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    plane = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    out = _k.dct2_plane_pallas(plane, inverse=inverse, interpret=interpret)
    return out.reshape(shape)


def idct2(x: jax.Array, interpret: bool | None = None) -> jax.Array:
    return dct2(x, inverse=True, interpret=interpret)
