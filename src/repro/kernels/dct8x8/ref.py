"""Pure-jnp oracle for the blocked 8x8 DCT/IDCT plane kernels.

Semantics: input is a 2-D plane (R, C) with R, C multiples of 8; every aligned
8x8 block is independently 2-D DCT-II transformed (Z = C X C^T) in place.
"""
import jax.numpy as jnp

from repro.core import dct as dct_lib


def dct2_plane(x: jnp.ndarray) -> jnp.ndarray:
    blocks = dct_lib._blockize(x)
    z = dct_lib.dct2_blocks(blocks, jnp.float32)
    return dct_lib._unblockize(z).astype(x.dtype)


def idct2_plane(z: jnp.ndarray) -> jnp.ndarray:
    blocks = dct_lib._blockize(z)
    x = dct_lib.idct2_blocks(blocks, jnp.float32)
    return dct_lib._unblockize(x).astype(z.dtype)
