"""Pallas TPU kernel: fused DCT-decompress + decode attention.

The dry-run measurement (EXPERIMENTS.md §Perf, yi-6b decode_32k) shows why
this kernel must exist: in pure XLA the compressed KV store DECOMPRESSES to
a full-size bf16 K/V in HBM before attention reads it — ~73 MB/layer of
traffic vs 34 MB raw, i.e. compression LOSES without fusion. This kernel is
the paper's architecture transplanted to TPU: compressed blocks stream from
HBM (int8, (k*k+4)/128 of bf16 bytes), the IDCT runs in VMEM as two skinny
constant matmuls, and the attention consumes K/V tiles that never exist in
HBM — the analogue of the paper's IDCT feeding the PE array "in one
computing stream".

Layout per (batch, kv-head) plane:
  packed_k/v : (S/8, hd/8, k, k) int8     scale_k/v : (S/8, hd/8) f32
  q          : (H, hd) — the n_rep query heads sharing this kv head
  out        : (H, hd) f32 — attention over the FLUSHED history
               (< pos//8*8; the raw 8-token tail is merged by ops.py with
               the same online-softmax algebra)

Grid: (S / TILE_S,) sequence tiles; the online-softmax running state
(m, l, acc) lives in VMEM scratch carried across sequentially-executed grid
steps.

VMEM per step (TILE_S=512, hd=128, keep=4): packed 2x16 KB int8 + scales
2x4 KB + decompressed K/V tiles 2x256 KB f32 + q/out/state ~130 KB — well
inside the ~16 MB budget, leaving room for double-buffered HBM pipelining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dct import _dct_matrix_np

BLOCK = 8


def _dct_k_np(keep: int) -> np.ndarray:
    return _dct_matrix_np(BLOCK)[:keep].astype(np.float32)


def _attend_kernel(
    pos_ref,                    # scalar prefetch: () int32
    pk_ref, sk_ref, pv_ref, sv_ref, q_ref, ck_ref,
    o_ref,
    m_ref, l_ref, acc_ref,      # VMEM scratch (carried)
    *, keep: int, tile_s: int, scale: float,
):
    ts8 = tile_s // BLOCK
    step = pl.program_id(0)
    ck = ck_ref[...]                           # (k, 8) DCT constant (VMEM)

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def dec(p_ref, s_ref):
        """int8 tile -> f32 (tile_s, hd): per-8x8-block z -> Ck^T z Ck."""
        z = p_ref[...].astype(jnp.float32) * s_ref[...][..., None, None]
        t = jnp.einsum("ua,ijuv,vb->ijab", ck, z, ck)   # (ts8, nh, 8, 8)
        t = jnp.swapaxes(t, 1, 2)                       # (ts8, 8, nh, 8)
        return t.reshape(ts8 * BLOCK, -1)

    kt = dec(pk_ref, sk_ref)
    vt = dec(pv_ref, sv_ref)

    q = q_ref[...].astype(jnp.float32) * scale          # (H, hd)
    s = jax.lax.dot(q, kt.T, preferred_element_type=jnp.float32)  # (H, tile_s)
    kv_pos = step * tile_s + jax.lax.broadcasted_iota(jnp.int32, (1, tile_s), 1)
    valid = kv_pos < (pos_ref[0] // BLOCK) * BLOCK      # flushed blocks only
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(valid, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, vt, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(step == pl.num_programs(0) - 1)
    def _finalize():
        # emit un-normalized stats so the caller can merge the raw tail
        o_ref[0] = acc_ref[...]
        o_ref[1] = jnp.broadcast_to(m_ref[...], acc_ref.shape)
        o_ref[2] = jnp.broadcast_to(l_ref[...], acc_ref.shape)


def attend_compressed_plane(
    packed_k: jax.Array,   # (S/8, hd/8, k, k) int8
    scale_k: jax.Array,    # (S/8, hd/8) f32
    packed_v: jax.Array,
    scale_v: jax.Array,
    q: jax.Array,          # (H, hd)
    pos: jax.Array,        # () int32
    *,
    tile_s: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decompress+attend over one (batch, kv-head) plane.

    Returns (acc (H, hd), m (H, hd) broadcast, l (H, hd) broadcast) —
    un-normalized online-softmax stats over the flushed history, ready for
    tail merging. out = acc / l after merging.
    """
    ns, nh, k, _ = packed_k.shape
    s_total = ns * BLOCK
    hd = nh * BLOCK
    h = q.shape[0]
    tile_s = min(tile_s, s_total)
    while s_total % tile_s:
        tile_s -= BLOCK
    ts8 = tile_s // BLOCK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s_total // tile_s,),
        in_specs=[
            pl.BlockSpec((ts8, nh, k, k), lambda i, pos: (i, 0, 0, 0)),
            pl.BlockSpec((ts8, nh), lambda i, pos: (i, 0)),
            pl.BlockSpec((ts8, nh, k, k), lambda i, pos: (i, 0, 0, 0)),
            pl.BlockSpec((ts8, nh), lambda i, pos: (i, 0)),
            pl.BlockSpec((h, hd), lambda i, pos: (0, 0)),
            pl.BlockSpec((k, BLOCK), lambda i, pos: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, h, hd), lambda i, pos: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),   # m
            pltpu.VMEM((h, 1), jnp.float32),   # l
            pltpu.VMEM((h, hd), jnp.float32),  # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_attend_kernel, keep=k, tile_s=tile_s,
                          scale=1.0 / float(np.sqrt(hd))),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((3, h, hd), jnp.float32),
        interpret=interpret,
    )(pos.reshape(1), packed_k, scale_k, packed_v, scale_v, q,
      jnp.asarray(_dct_k_np(k)))
    acc, m_b, l_b = out[0], out[1], out[2]
    return acc, m_b[:, :1], l_b[:, :1]


# ---------------------------------------------------------------------------
# Paged pool: gather history through the block table (scalar prefetch)
# ---------------------------------------------------------------------------

def _attend_paged_kernel(
    pos_ref,                    # scalar prefetch: (B,) int32
    bt_ref,                     # scalar prefetch: (B, nblocks) int32 page ids
    pk_ref, sk_ref, pv_ref, sv_ref, q_ref, ck_ref,
    o_ref,
    m_ref, l_ref, acc_ref,      # VMEM scratch (carried per (b, h) plane)
    *, keep: int, scale: float,
):
    b = pl.program_id(0)
    step = pl.program_id(2)     # one 8-token block group per grid step
    ck = ck_ref[...]            # (k, 8) DCT constant (VMEM)

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def dec(p_ref, s_ref):
        """One int8 page -> f32 (8, hd): per-8x8-block z -> Ck^T z Ck."""
        z = p_ref[0, 0].astype(jnp.float32) * s_ref[0, 0][..., None, None]
        t = jnp.einsum("ua,juv,vb->ajb", ck, z, ck)     # (8, nh, 8)
        return t.reshape(BLOCK, -1)

    kt = dec(pk_ref, sk_ref)
    vt = dec(pv_ref, sv_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale         # (n_rep, hd)
    s = jax.lax.dot(q, kt.T, preferred_element_type=jnp.float32)  # (n_rep, 8)
    kv_pos = step * BLOCK + jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK), 1)
    valid = kv_pos < (pos_ref[b] // BLOCK) * BLOCK      # flushed blocks only
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(valid, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, vt, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(step == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0, 0] = acc_ref[...]
        o_ref[0, 0, 1] = jnp.broadcast_to(m_ref[...], acc_ref.shape)
        o_ref[0, 0, 2] = jnp.broadcast_to(l_ref[...], acc_ref.shape)


def attend_paged(
    packed_k: jax.Array,   # (P, Hkv, hd/8, k, k) int8 page pool
    scale_k: jax.Array,    # (P, Hkv, hd/8) f32
    packed_v: jax.Array,
    scale_v: jax.Array,
    q: jax.Array,          # (B, Hkv, n_rep, hd)
    pos: jax.Array,        # (B,) int32 per-slot positions
    block_table: jax.Array,  # (B, S/8) int32 page ids
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decompress+attend over the PAGED pool, all (batch, kv-head)
    planes in one explicit grid.

    The block table rides the scalar-prefetch path beside `pos`: each grid
    step's BlockSpec index_map dereferences `bt[b, i]`, so the kernel DMAs
    exactly the pages the slot owns — HBM traffic is the compressed pages
    the block table names, never the dense (B, S/8, ...) layout.  Unmapped
    table entries are 0 (a valid page) and masked by the flushed watermark.

    Returns un-normalized online-softmax stats (acc (B, Hkv, n_rep, hd),
    m/l (B, Hkv, n_rep, 1)) ready for the raw-tail merge in ops.py.
    """
    n_pages, hkv, nh, k, _ = packed_k.shape
    hd = nh * BLOCK
    b, _, n_rep, _ = q.shape
    nblocks = block_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nblocks),
        in_specs=[
            pl.BlockSpec((1, 1, nh, k, k),
                         lambda bi, h, i, pos, bt: (bt[bi, i], h, 0, 0, 0)),
            pl.BlockSpec((1, 1, nh),
                         lambda bi, h, i, pos, bt: (bt[bi, i], h, 0)),
            pl.BlockSpec((1, 1, nh, k, k),
                         lambda bi, h, i, pos, bt: (bt[bi, i], h, 0, 0, 0)),
            pl.BlockSpec((1, 1, nh),
                         lambda bi, h, i, pos, bt: (bt[bi, i], h, 0)),
            pl.BlockSpec((1, 1, n_rep, hd),
                         lambda bi, h, i, pos, bt: (bi, h, 0, 0)),
            pl.BlockSpec((k, BLOCK), lambda bi, h, i, pos, bt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 3, n_rep, hd),
                               lambda bi, h, i, pos, bt: (bi, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_rep, 1), jnp.float32),   # m
            pltpu.VMEM((n_rep, 1), jnp.float32),   # l
            pltpu.VMEM((n_rep, hd), jnp.float32),  # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_attend_paged_kernel, keep=k,
                          scale=1.0 / float(np.sqrt(hd))),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, 3, n_rep, hd), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), block_table.astype(jnp.int32),
      packed_k, scale_k, packed_v, scale_v, q, jnp.asarray(_dct_k_np(k)))
    acc, m_b, l_b = out[:, :, 0], out[:, :, 1], out[:, :, 2]
    return acc, m_b[..., :1], l_b[..., :1]
