"""Pallas TPU kernel: fused DCT-decompress + decode attention.

The dry-run measurement (EXPERIMENTS.md §Perf, yi-6b decode_32k) shows why
this kernel must exist: in pure XLA the compressed KV store DECOMPRESSES to
a full-size bf16 K/V in HBM before attention reads it — ~73 MB/layer of
traffic vs 34 MB raw, i.e. compression LOSES without fusion. This kernel is
the paper's architecture transplanted to TPU: compressed blocks stream from
HBM (int8, (k*k+4)/128 of bf16 bytes), the IDCT runs in VMEM as two skinny
constant matmuls, and the attention consumes K/V tiles that never exist in
HBM — the analogue of the paper's IDCT feeding the PE array "in one
computing stream".

Dense plane kernel (attend_compressed_plane), per (batch, kv-head) plane:
  packed_k/v : (S/8, hd/8, k, k) int8     scale_k/v : (S/8, hd/8) f32
  q          : (H, hd) — the n_rep query heads sharing this kv head
  out        : (H, hd) f32 — attention over the FLUSHED history
               (< pos//8*8; the raw 8-token tail is merged by ops.py with
               the same online-softmax algebra)
  Grid: (S / TILE_S,) sequence tiles; the online-softmax running state
  (m, l, acc) lives in VMEM scratch carried across sequentially-executed
  grid steps.

Paged pool kernel (attend_paged), all planes in one explicit grid:
  Grid: (B, Hkv, nblocks / G) — each grid step gathers G pages through the
  block table (page ids ride the scalar-prefetch path, so every page DMA is
  issued from SMEM-resident table entries), decompresses them into one
  (G*8, hd) K/V tile, and runs MXU-shaped (n_rep, G*8) score / PV matmuls.
  A tile whose first position is at or past the slot's flushed watermark
  skips its decompress + matmuls entirely under `pl.when` (skipped tiles
  contribute exactly nothing to the online-softmax state, so the output is
  unchanged).  The finalize step merges the raw 8-token tail ring with the
  same online-softmax algebra and NORMALIZES, so one pallas_call emits the
  finished attention output — no separate XLA tail pass.  `nblocks` is
  whatever table width the caller hands in: the serve engine slices the
  table to a decode-ladder bucket covering the deepest live context
  (core.kv_cache.table_view), so the grid tracks occupancy, not pool
  capacity.

VMEM per grid step stays far inside the ~16 MB budget for every supported
geometry (see the README kernel section for the per-(G, keep, hd) table);
the dominant term is the two decompressed f32 tiles, 2 * G*8 * hd * 4 B.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dct import _dct_matrix_np

BLOCK = 8


def _dct_k_np(keep: int) -> np.ndarray:
    return _dct_matrix_np(BLOCK)[:keep].astype(np.float32)


def _resolve_interpret(interpret: bool | None) -> bool:
    """Platform auto-selection via the codec dispatch rules — compiled on
    TPU, interpret elsewhere (CPU CI), REPRO_CODEC_INTERPRET override. The
    same resolution ops.py applies, so direct kernel callers never silently
    run interpreted on TPU."""
    from repro.codec import dispatch as codec_dispatch  # lazy: no cycle

    return codec_dispatch.resolve_interpret(interpret)


def fit_tile(requested: int, total: int, unit: int = BLOCK) -> int:
    """Largest multiple of `unit` dividing `total`, capped at `requested`.

    The explicit tile-shrink rule shared by the sequence tiling (unit=8
    tokens) and the page tiling (unit=1 page): the result is asserted to be
    a unit multiple that divides `total` exactly — never a silent shrink to
    a non-aligned width."""
    assert total >= unit and total % unit == 0, (total, unit)
    t = max(min(requested - requested % unit, total), unit)
    while total % t:
        t -= unit
    assert unit <= t <= total and total % t == 0 and t % unit == 0, \
        (requested, total, unit, t)
    return t


def _attend_kernel(
    pos_ref,                    # scalar prefetch: () int32
    pk_ref, sk_ref, pv_ref, sv_ref, q_ref, ck_ref,
    o_ref,
    m_ref, l_ref, acc_ref,      # VMEM scratch (carried)
    *, tile_s: int, scale: float,
):
    ts8 = tile_s // BLOCK
    step = pl.program_id(0)
    ck = ck_ref[...]                           # (k, 8) DCT constant (VMEM)

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def dec(p_ref, s_ref):
        """int8 tile -> f32 (tile_s, hd): per-8x8-block z -> Ck^T z Ck."""
        z = p_ref[...].astype(jnp.float32) * s_ref[...][..., None, None]
        t = jnp.einsum("ua,ijuv,vb->ijab", ck, z, ck)   # (ts8, nh, 8, 8)
        t = jnp.swapaxes(t, 1, 2)                       # (ts8, 8, nh, 8)
        return t.reshape(ts8 * BLOCK, -1)

    kt = dec(pk_ref, sk_ref)
    vt = dec(pv_ref, sv_ref)

    q = q_ref[...].astype(jnp.float32) * scale          # (H, hd)
    s = jax.lax.dot(q, kt.T, preferred_element_type=jnp.float32)  # (H, tile_s)
    kv_pos = step * tile_s + jax.lax.broadcasted_iota(jnp.int32, (1, tile_s), 1)
    valid = kv_pos < (pos_ref[0] // BLOCK) * BLOCK      # flushed blocks only
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(valid, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, vt, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(step == pl.num_programs(0) - 1)
    def _finalize():
        # emit un-normalized stats so the caller can merge the raw tail
        o_ref[0] = acc_ref[...]
        o_ref[1] = jnp.broadcast_to(m_ref[...], acc_ref.shape)
        o_ref[2] = jnp.broadcast_to(l_ref[...], acc_ref.shape)


def attend_compressed_plane(
    packed_k: jax.Array,   # (S/8, hd/8, k, k) int8
    scale_k: jax.Array,    # (S/8, hd/8) f32
    packed_v: jax.Array,
    scale_v: jax.Array,
    q: jax.Array,          # (H, hd)
    pos: jax.Array,        # () int32
    *,
    tile_s: int = 512,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decompress+attend over one (batch, kv-head) plane.

    Returns (acc (H, hd), m (H, hd) broadcast, l (H, hd) broadcast) —
    un-normalized online-softmax stats over the flushed history, ready for
    tail merging. out = acc / l after merging. interpret=None auto-selects
    via the codec dispatch rules (compiled on TPU, interpret elsewhere).
    """
    interpret = _resolve_interpret(interpret)
    ns, nh, k, _ = packed_k.shape
    s_total = ns * BLOCK
    hd = nh * BLOCK
    h = q.shape[0]
    tile_s = fit_tile(tile_s, s_total)
    ts8 = tile_s // BLOCK

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s_total // tile_s,),
        in_specs=[
            pl.BlockSpec((ts8, nh, k, k), lambda i, pos: (i, 0, 0, 0)),
            pl.BlockSpec((ts8, nh), lambda i, pos: (i, 0)),
            pl.BlockSpec((ts8, nh, k, k), lambda i, pos: (i, 0, 0, 0)),
            pl.BlockSpec((ts8, nh), lambda i, pos: (i, 0)),
            pl.BlockSpec((h, hd), lambda i, pos: (0, 0)),
            pl.BlockSpec((k, BLOCK), lambda i, pos: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, h, hd), lambda i, pos: (0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),   # m
            pltpu.VMEM((h, 1), jnp.float32),   # l
            pltpu.VMEM((h, hd), jnp.float32),  # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_attend_kernel, tile_s=tile_s,
                          scale=1.0 / float(np.sqrt(hd))),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((3, h, hd), jnp.float32),
        interpret=interpret,
    )(pos.reshape(1), packed_k, scale_k, packed_v, scale_v, q,
      jnp.asarray(_dct_k_np(k)))
    acc, m_b, l_b = out[0], out[1], out[2]
    return acc, m_b[:, :1], l_b[:, :1]


# ---------------------------------------------------------------------------
# Paged pool: gather history through the block table (scalar prefetch)
# ---------------------------------------------------------------------------

def _attend_paged_kernel(
    pos_ref,                    # scalar prefetch: (B,) int32
    bt_ref,                     # scalar prefetch: (B, nblocks) int32 page ids
    *refs,                      # 4*G page refs, q, ck, tails, out, scratch
    g_pages: int, scale: float,
):
    page_refs = refs[:4 * g_pages]      # (pk, sk, pv, sv) per gathered page
    (q_ref, ck_ref, tk_ref, tv_ref, o_ref,
     m_ref, l_ref, acc_ref) = refs[4 * g_pages:]
    b = pl.program_id(0)
    step = pl.program_id(2)             # one G-page tile per grid step
    tile_s = g_pages * BLOCK
    ck = ck_ref[...]                    # (k, 8) DCT constant (VMEM)

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    flushed = (pos_ref[b] // BLOCK) * BLOCK
    tile0 = step * tile_s

    @pl.when(tile0 < flushed)           # skip tiles wholly past the watermark
    def _tile():
        def dec(p_ref, s_ref):
            """One int8 page -> f32 (8, hd): per-8x8-block z -> Ck^T z Ck."""
            z = p_ref[0, 0].astype(jnp.float32) * s_ref[0, 0][..., None, None]
            t = jnp.einsum("ua,juv,vb->ajb", ck, z, ck)     # (8, nh, 8)
            return t.reshape(BLOCK, -1)

        kt = jnp.concatenate(
            [dec(page_refs[4 * g], page_refs[4 * g + 1])
             for g in range(g_pages)], axis=0)              # (G*8, hd)
        vt = jnp.concatenate(
            [dec(page_refs[4 * g + 2], page_refs[4 * g + 3])
             for g in range(g_pages)], axis=0)

        q = q_ref[0, 0].astype(jnp.float32) * scale         # (n_rep, hd)
        s = jax.lax.dot(q, kt.T, preferred_element_type=jnp.float32)
        kv_pos = tile0 + jax.lax.broadcasted_iota(jnp.int32, (1, tile_s), 1)
        valid = kv_pos < flushed        # flushed blocks only
        s = jnp.where(valid, s, -jnp.inf)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid, jnp.exp(s - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, vt, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(step == pl.num_programs(2) - 1)
    def _finalize():
        # fused raw-tail merge: positions flushed..pos sit in the 8-token
        # tail ring; same online-softmax algebra, then normalize — the
        # kernel output is the finished attention, no XLA pass after it.
        q = q_ref[0, 0].astype(jnp.float32) * scale
        tk = tk_ref[0, :, 0].astype(jnp.float32)            # (8, hd)
        tv = tv_ref[0, :, 0].astype(jnp.float32)
        st = jax.lax.dot(q, tk.T, preferred_element_type=jnp.float32)
        tail_pos = flushed + jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK), 1)
        tvalid = tail_pos <= pos_ref[b]
        st = jnp.where(tvalid, st, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(st, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        pt = jnp.where(tvalid, jnp.exp(st - m_safe), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l2 = l_ref[...] * alpha + jnp.sum(pt, axis=-1, keepdims=True)
        acc2 = acc_ref[...] * alpha + jax.lax.dot(
            pt, tv, preferred_element_type=jnp.float32)
        o_ref[0, 0] = acc2 / jnp.maximum(l2, 1e-30)


def attend_paged(
    packed_k: jax.Array,   # (P, Hkv, hd/8, k, k) int8 page pool
    scale_k: jax.Array,    # (P, Hkv, hd/8) f32
    packed_v: jax.Array,
    scale_v: jax.Array,
    q: jax.Array,          # (B, Hkv, n_rep, hd)
    pos: jax.Array,        # (B,) int32 per-slot positions
    block_table: jax.Array,  # (B, nblocks) page ids (maybe a bucket slice)
    tail_k: jax.Array,     # (B, 8, Hkv, hd) raw tail ring
    tail_v: jax.Array,
    *,
    pages_per_tile: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused decompress+attend+tail over the PAGED pool, all (batch,
    kv-head) planes in one explicit grid.

    The block table rides the scalar-prefetch path beside `pos`: each grid
    step's BlockSpec index_maps dereference `bt[b, i*G + g]` for the tile's
    G pages, so the kernel DMAs exactly the pages the table names — HBM
    traffic is the compressed pages, never the dense (B, S/8, ...) layout.
    Unmapped table entries are 0 (a valid page, masked by the flushed
    watermark); tiles wholly past the watermark skip compute via pl.when.
    `block_table` may be a decode-ladder bucket slice of the full table —
    the grid covers only the slice. `pages_per_tile` shrinks to the largest
    divisor of the table width (G=1 reproduces single-page stepping).

    Returns the NORMALIZED attention output (B, Hkv, n_rep, hd) f32 — the
    raw-tail merge runs in the kernel's finalize step.
    """
    interpret = _resolve_interpret(interpret)
    n_pages, hkv, nh, k, _ = packed_k.shape
    hd = nh * BLOCK
    b, _, n_rep, _ = q.shape
    nblocks = block_table.shape[1]
    g_pages = fit_tile(pages_per_tile, nblocks, unit=1)

    page_specs = []
    for g in range(g_pages):
        idx5 = lambda bi, h, i, pos, bt, g=g: \
            (bt[bi, i * g_pages + g], h, 0, 0, 0)
        idx3 = lambda bi, h, i, pos, bt, g=g: (bt[bi, i * g_pages + g], h, 0)
        page_specs += [
            pl.BlockSpec((1, 1, nh, k, k), idx5),
            pl.BlockSpec((1, 1, nh), idx3),
            pl.BlockSpec((1, 1, nh, k, k), idx5),
            pl.BlockSpec((1, 1, nh), idx3),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nblocks // g_pages),
        in_specs=page_specs + [
            pl.BlockSpec((1, 1, n_rep, hd),
                         lambda bi, h, i, pos, bt: (bi, h, 0, 0)),
            pl.BlockSpec((k, BLOCK), lambda bi, h, i, pos, bt: (0, 0)),
            pl.BlockSpec((1, BLOCK, 1, hd),
                         lambda bi, h, i, pos, bt: (bi, 0, h, 0)),
            pl.BlockSpec((1, BLOCK, 1, hd),
                         lambda bi, h, i, pos, bt: (bi, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, n_rep, hd),
                               lambda bi, h, i, pos, bt: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_rep, 1), jnp.float32),   # m
            pltpu.VMEM((n_rep, 1), jnp.float32),   # l
            pltpu.VMEM((n_rep, hd), jnp.float32),  # acc
        ],
    )
    # the same pool arrays are passed once per tile lane: each lane's
    # BlockSpec walks its own table stride, XLA aliases the operands
    pages = (packed_k, scale_k, packed_v, scale_v) * g_pages
    return pl.pallas_call(
        functools.partial(_attend_paged_kernel, g_pages=g_pages,
                          scale=1.0 / float(np.sqrt(hd))),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, n_rep, hd), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), block_table.astype(jnp.int32),
      *pages, q, jnp.asarray(_dct_k_np(k)), tail_k, tail_v)
