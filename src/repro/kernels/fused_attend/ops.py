"""jit-able wrapper: fused kernel over all (batch, kv-head) planes — the
drop-in decode attention for the compressed KV cache.  The dense-plane path
merges the raw tail here in XLA; the paged kernel fuses the tail merge into
its finalize step and returns the normalized output directly."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import dispatch as codec_dispatch
from repro.core.kv_cache import as_pos_vec
from repro.kernels.fused_attend.kernel import (
    attend_compressed_plane,
    attend_paged,
)
from repro.parallel.sharding import attn_hint

BLOCK = 8


def attend_with_tail(
    q: jax.Array,                 # (B, 1, H, hd)
    layer_cache: dict,            # per-layer compressed cache slices
    pos: jax.Array,               # (B,) per-slot positions (scalar broadcasts)
    *,
    tile_s: int = 512,
    interpret: bool | None = None,
    block_table: jax.Array | None = None,  # (B, nblocks) page ids (paged)
    pages_per_tile: int = 8,
) -> jax.Array:
    """Kernel-backed equivalent of core.kv_cache.attend_compressed.

    `pos` is a per-slot vector: the batch vmap maps it alongside the cache
    planes, so every row's kernel invocation masks against that row's own
    flushed watermark. interpret=None auto-selects via the codec dispatch
    rules: compiled on TPU, interpret elsewhere (CPU CI).

    With `block_table` the cache planes are the shared page pool and the
    fused paged kernel gathers G pages per grid step through the table
    (page ids on the scalar-prefetch path) and merges the raw tail in its
    finalize step — one pallas_call emits the normalized output.  The
    table may be a decode-ladder bucket slice of the full table (see
    core.kv_cache.table_view): the kernel grid covers only the slice.
    """
    interpret = codec_dispatch.resolve_interpret(interpret)
    b, _, h, hd = q.shape
    pk = layer_cache["packed_k"]
    hkv = pk.shape[1] if block_table is not None else pk.shape[2]
    n_rep = h // hkv
    pos = as_pos_vec(pos, b)
    qg = q[:, 0].reshape(b, hkv, n_rep, hd)

    if block_table is not None:
        out = attend_paged(
            layer_cache["packed_k"], layer_cache["scale_k"],
            layer_cache["packed_v"], layer_cache["scale_v"],
            qg, pos, block_table,
            layer_cache["tail_k"], layer_cache["tail_v"],
            pages_per_tile=pages_per_tile, interpret=interpret,
        )  # (B, Hkv, n_rep, hd) normalized — tail merged in-kernel
        return attn_hint(out.reshape(b, 1, h, hd).astype(q.dtype))

    # (B, S/8, Hkv, hd/8, k, k) -> planes (B, Hkv, S/8, hd/8, k, k)
    def plane_axes(x):
        return jnp.swapaxes(x, 1, 2)

    kern = functools.partial(attend_compressed_plane, tile_s=tile_s,
                             interpret=interpret)
    # vmap over batch (pos mapped: per-slot horizon) then kv-head
    # (shared pos)
    acc, m, l = jax.vmap(jax.vmap(kern, in_axes=(0, 0, 0, 0, 0, None)),
                         in_axes=(0, 0, 0, 0, 0, 0))(
        plane_axes(layer_cache["packed_k"]), plane_axes(layer_cache["scale_k"]),
        plane_axes(layer_cache["packed_v"]), plane_axes(layer_cache["scale_v"]),
        qg, pos,
    )  # acc (B, Hkv, n_rep, hd), m/l (B, Hkv, n_rep, 1)

    # ---- merge the raw tail (positions pos//8*8 .. pos, per row) ----------
    tk = jnp.swapaxes(layer_cache["tail_k"], 1, 2).astype(jnp.float32)  # (B,Hkv,8,hd)
    tv = jnp.swapaxes(layer_cache["tail_v"], 1, 2).astype(jnp.float32)
    qf = qg.astype(jnp.float32) / np.sqrt(hd)
    st = jnp.einsum("bgrd,bgtd->bgrt", qf, tk)          # (B, Hkv, rep, 8)
    flushed = (pos // BLOCK) * BLOCK
    tail_pos = flushed[:, None] + jnp.arange(BLOCK)     # (B, 8)
    tvalid = (tail_pos <= pos[:, None])[:, None, None]  # (B, 1, 1, 8)
    st = jnp.where(tvalid, st, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(st, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    pt = jnp.where(tvalid, jnp.exp(st - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l2 = l * alpha + jnp.sum(pt, axis=-1, keepdims=True)
    acc2 = acc * alpha + jnp.einsum("bgrt,bgtd->bgrd", pt, tv)
    out = acc2 / jnp.maximum(l2, 1e-30)
    # under a serve mesh keep the merged output head-sharded like the packed
    # planes it came from (slots on data, heads on model when divisible)
    return attn_hint(out.reshape(b, 1, h, hd).astype(q.dtype))
