"""Pure-jnp oracle for the fused decompress+attend kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv_cache as kvc

BLOCK = 8


def attend_compressed_plane_ref(packed_k, scale_k, packed_v, scale_v, q, pos):
    """Oracle: decompress fully, masked softmax stats over flushed history.

    Returns (acc, m, l) matching kernel.attend_compressed_plane.
    """
    kt = kvc.decompress_kv_blocks(packed_k[None], scale_k[None], jnp.float32)[0]
    vt = kvc.decompress_kv_blocks(packed_v[None], scale_v[None], jnp.float32)[0]
    hd = kt.shape[-1]
    s_total = kt.shape[0]
    qf = q.astype(jnp.float32) / np.sqrt(hd)
    s = qf @ kt.T                                       # (H, S)
    valid = jnp.arange(s_total) < (pos // BLOCK) * BLOCK
    s = jnp.where(valid[None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid[None], jnp.exp(s - m_safe), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = p @ vt
    return acc, m, l
