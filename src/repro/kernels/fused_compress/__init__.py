"""fused_compress kernel package (dispatch lives in repro.codec; ops.py shim removed)."""
from repro.kernels.fused_compress import kernel, ref
