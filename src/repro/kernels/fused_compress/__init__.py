"""fused_compress kernel package."""
from repro.kernels.fused_compress import kernel, ops, ref
