"""Pallas TPU kernels: fused DCT + frequency-truncation + int8 quant codec.

This is the paper's "combine compression, decompression, and CNN acceleration
into one computing stream" adapted to TPU (DESIGN.md §2): activations make
exactly ONE HBM round-trip in compressed form; the transform+quant happens in
VMEM at the compute boundary.

Key identity: truncating Z = C X C^T to its kxk low-frequency corner equals

    packed = kron(I, C[:k,:]) @ X @ kron(I, C[:k,:])^T

i.e. fused DCT+truncation is two *skinny rectangular matmuls* with constant
operands — the compressed tile never exists in full 8x8 form.  Decompression
is the transpose pair.  Both run at full MXU rate; the skinny constant means
the compress matmuls also do ~k/8 of the FLOPs of a full transform.

VMEM per grid step (TR=TC=128, k=4): in 64 KB f32 + out 8 KB int8 + consts
2*32 KB — tiny; the Pallas pipeline double-buffers HBM<->VMEM around it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.dct import _dct_matrix_np

BLOCK = 8


@functools.lru_cache(maxsize=None)
def block_diag_dct_rows_np(size: int, keep: int) -> np.ndarray:
    """kron(I_{size/8}, C8[:keep, :]) — fused DCT+truncate constant."""
    assert size % BLOCK == 0
    ck = _dct_matrix_np(BLOCK).astype(np.float32)[:keep, :]
    return np.kron(np.eye(size // BLOCK, dtype=np.float32), ck)


def _compress_kernel(x_ref, bdr_ref, bdc_ref, packed_ref, scale_ref, *, keep: int):
    x = x_ref[...].astype(jnp.float32)
    # fused DCT + corner extraction: (TR*k/8, TC*k/8)
    z = jax.lax.dot(bdr_ref[...], x, preferred_element_type=jnp.float32)
    z = jax.lax.dot(z, bdc_ref[...].T, preferred_element_type=jnp.float32)
    nh = z.shape[0] // keep
    nw = z.shape[1] // keep
    zb = z.reshape(nh, keep, nw, keep)
    amax = jnp.max(jnp.abs(zb), axis=(1, 3), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(zb / scale), -127, 127)
    packed_ref[...] = q.reshape(z.shape).astype(jnp.int8)
    scale_ref[...] = scale[:, 0, :, 0]


def _decompress_kernel(packed_ref, scale_ref, bdr_ref, bdc_ref, o_ref, *, keep: int):
    q = packed_ref[...].astype(jnp.float32)
    scale = scale_ref[...]
    nh, nw = scale.shape
    zb = q.reshape(nh, keep, nw, keep) * scale[:, None, :, None]
    z = zb.reshape(q.shape)
    # X = bdr_k^T @ Z_packed @ bdc_k  (zero-pad corner + IDCT, fused)
    x = jax.lax.dot(bdr_ref[...].T, z, preferred_element_type=jnp.float32)
    x = jax.lax.dot(x, bdc_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = x.astype(o_ref.dtype)


def compress_plane_pallas(
    x: jax.Array,
    keep: int,
    *,
    tile_r: int = 128,
    tile_c: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    r, c = x.shape
    assert r % BLOCK == 0 and c % BLOCK == 0, (r, c)
    tr = min(tile_r, r)
    tc = min(tile_c, c)
    pr = (-r) % tr
    pc = (-c) % tc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    rp, cp = xp.shape
    kb = keep  # corner size
    bdr = jnp.asarray(block_diag_dct_rows_np(tr, kb))
    bdc = jnp.asarray(block_diag_dct_rows_np(tc, kb))

    packed, scale = pl.pallas_call(
        functools.partial(_compress_kernel, keep=kb),
        grid=(rp // tr, cp // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr * kb // BLOCK, tr), lambda i, j: (0, 0)),
            pl.BlockSpec((tc * kb // BLOCK, tc), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr * kb // BLOCK, tc * kb // BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((tr // BLOCK, tc // BLOCK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp * kb // BLOCK, cp * kb // BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rp // BLOCK, cp // BLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(xp, bdr, bdc)
    return packed[: r * kb // BLOCK, : c * kb // BLOCK], scale[: r // BLOCK, : c // BLOCK]


def decompress_plane_pallas(
    packed: jax.Array,
    scale: jax.Array,
    keep: int,
    *,
    out_dtype=jnp.float32,
    tile_r: int = 128,
    tile_c: int = 128,
    interpret: bool = True,
) -> jax.Array:
    nh, nw = scale.shape
    r, c = nh * BLOCK, nw * BLOCK
    tr = min(tile_r, r)
    tc = min(tile_c, c)
    pr = (-r) % tr
    pc = (-c) % tc
    kb = keep
    if pr or pc:
        packed = jnp.pad(packed, ((0, pr * kb // BLOCK), (0, pc * kb // BLOCK)))
        scale = jnp.pad(scale, ((0, pr // BLOCK), (0, pc // BLOCK)))
    rp, cp = r + pr, c + pc
    bdr = jnp.asarray(block_diag_dct_rows_np(tr, kb))
    bdc = jnp.asarray(block_diag_dct_rows_np(tc, kb))

    out = pl.pallas_call(
        functools.partial(_decompress_kernel, keep=kb),
        grid=(rp // tr, cp // tc),
        in_specs=[
            pl.BlockSpec((tr * kb // BLOCK, tc * kb // BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((tr // BLOCK, tc // BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((tr * kb // BLOCK, tr), lambda i, j: (0, 0)),
            pl.BlockSpec((tc * kb // BLOCK, tc), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), out_dtype),
        interpret=interpret,
    )(packed, scale, bdr, bdc)
    return out[:r, :c]
