"""Jitted public wrappers for the fused compress/decompress kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_compress import kernel as _k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("keep", "interpret"))
def compress(x: jax.Array, keep: int, interpret: bool | None = None):
    """Fused DCT+truncate+int8 of (..., R, C); R % 8 == C % 8 == 0.

    Returns (packed int8 (..., R*k/8, C*k/8), scale f32 (..., R/8, C/8)).
    """
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    if x.ndim == 2:
        return _k.compress_plane_pallas(x, keep, interpret=interpret)
    plane = x.reshape(-1, shape[-1])
    packed, scale = _k.compress_plane_pallas(plane, keep, interpret=interpret)
    lead = shape[:-2]
    r, c = shape[-2], shape[-1]
    return (
        packed.reshape(*lead, r * keep // 8, c * keep // 8),
        scale.reshape(*lead, r // 8, c // 8),
    )


@functools.partial(jax.jit, static_argnames=("keep", "out_dtype", "interpret"))
def decompress(
    packed: jax.Array,
    scale: jax.Array,
    keep: int,
    out_dtype=jnp.float32,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = not _on_tpu()
    if packed.ndim == 2:
        return _k.decompress_plane_pallas(
            packed, scale, keep, out_dtype=out_dtype, interpret=interpret
        )
    lead = packed.shape[:-2]
    p2 = packed.reshape(-1, packed.shape[-1])
    s2 = scale.reshape(-1, scale.shape[-1])
    out = _k.decompress_plane_pallas(
        p2, s2, keep, out_dtype=out_dtype, interpret=interpret
    )
    r = scale.shape[-2] * 8
    c = scale.shape[-1] * 8
    return out.reshape(*lead, r, c)
