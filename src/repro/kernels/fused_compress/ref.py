"""Pure-jnp oracle for the fused compress/decompress Pallas kernels.

Semantics (the TPU runtime codec, DESIGN.md §2): for a plane (R, C) with R, C
multiples of 8 and corner size k:

  compress:   per 8x8 block B, Z = C8 B C8^T; keep the kxk low-frequency
              corner; per-block symmetric int8 quantization.
              outputs: packed (R*k/8, C*k/8) int8 plane (corners tiled in
              block order), scale (R/8, C/8) f32.
  decompress: exact inverse (dequant, zero-pad corner to 8x8, IDCT).
"""
import jax.numpy as jnp

from repro.core import dct as dct_lib

BLOCK = 8


def compress_plane(x: jnp.ndarray, keep: int):
    r, c = x.shape
    blocks = dct_lib._blockize(x.astype(jnp.float32))          # (r/8, c/8, 8, 8)
    coefs = dct_lib.dct2_blocks(blocks)
    corner = coefs[..., :keep, :keep]
    amax = jnp.max(jnp.abs(corner), axis=(-1, -2), keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(corner / scale), -127, 127).astype(jnp.int8)
    packed = dct_lib._unblockize(q)                            # (r*k/8, c*k/8)
    return packed, scale[..., 0, 0]


def decompress_plane(packed: jnp.ndarray, scale: jnp.ndarray, keep: int, dtype=jnp.float32):
    nh, nw = scale.shape
    q = dct_lib._blockize(packed, keep)                        # (nh, nw, k, k)
    corner = q.astype(jnp.float32) * scale[..., None, None]
    full = jnp.zeros((nh, nw, BLOCK, BLOCK), jnp.float32)
    full = full.at[..., :keep, :keep].set(corner)
    x = dct_lib.idct2_blocks(full)
    return dct_lib._unblockize(x).astype(dtype)
