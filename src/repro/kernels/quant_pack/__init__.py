"""quant_pack kernel package (dispatch lives in repro.codec; ops.py shim removed)."""
from repro.kernels.quant_pack import kernel, ref
