"""quant_pack kernel package."""
from repro.kernels.quant_pack import kernel, ops, ref
