"""Pallas TPU kernel: paper-exact two-step quantization + bitmap index.

Implements Eq. 7-8 (with the JPEG level shift, DESIGN.md §6) over a plane of
DCT coefficients: per grid tile, affine min-max quantization against the global
(fmin, fmax) range, Q-table division (the 8x8 table pre-tiled to the VMEM tile
shape so the divide is a plain elementwise op), zero detection for the 1-bit
index buffer, and a per-tile non-zero count for the compression-ratio
accounting — all in one VMEM pass, mirroring the paper's single computing
stream where quantization and encoding sit between the non-linear module and
the SRAM write port.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8


def _quant_pack_kernel(x_ref, rng_ref, qt_ref, q2_ref, idx_ref, nnz_ref, *, imax: int):
    x = x_ref[...].astype(jnp.float32)
    fmin = rng_ref[0, 0]
    fmax = rng_ref[0, 1]
    scale = imax / (fmax - fmin)
    q1 = jnp.clip(jnp.round((x - fmin) * scale), 0, imax)          # Eq. 7
    zp = jnp.round(jnp.clip(-fmin * scale, 0, imax))               # level shift
    q2 = jnp.round((q1 - zp) / qt_ref[...])                        # Eq. 8
    idx = (q2 != 0).astype(jnp.int8)
    q2_ref[...] = q2.astype(jnp.int32)
    idx_ref[...] = idx
    nnz_ref[0, 0] = jnp.sum(idx.astype(jnp.int32))


def quant_pack_plane_pallas(
    x: jax.Array,
    fmin,
    fmax,
    qt_plane: jax.Array,
    *,
    bits: int = 8,
    tile_r: int = 128,
    tile_c: int = 128,
    interpret: bool = True,
):
    r, c = x.shape
    assert r % BLOCK == 0 and c % BLOCK == 0
    tr = min(tile_r, r)
    tc = min(tile_c, c)
    pr = (-r) % tr
    pc = (-c) % tc
    xp = jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x
    qtp = (
        jnp.pad(qt_plane, ((0, pr), (0, pc)), constant_values=1.0)
        if (pr or pc)
        else qt_plane
    )
    rp, cp = xp.shape
    rng = jnp.array([[fmin, fmax]], jnp.float32)

    q2, idx, nnz = pl.pallas_call(
        functools.partial(_quant_pack_kernel, imax=(1 << bits) - 1),
        grid=(rp // tr, cp // tc),
        in_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), jnp.int32),
            jax.ShapeDtypeStruct((rp, cp), jnp.int8),
            jax.ShapeDtypeStruct((rp // tr, cp // tc), jnp.int32),
        ],
        interpret=interpret,
    )(xp, rng, qtp)
    # Padded blocks quantize the zero-pad: their q2 == round((zp-zp)/qt) == 0,
    # so they contribute nothing to nnz and slicing them off is exact.
    return q2[:r, :c], idx[:r, :c], jnp.sum(nnz)
