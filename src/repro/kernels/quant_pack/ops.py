"""Jitted public wrapper for the quant_pack kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_pack import kernel as _k
from repro.kernels.quant_pack import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("level", "bits", "interpret"))
def quant_pack(
    x: jax.Array,
    fmin,
    fmax,
    level: int = 1,
    bits: int = 8,
    interpret: bool | None = None,
):
    """Paper-exact quantize+index of a DCT-coefficient plane (R%8==C%8==0).

    Returns (q2 int32 plane, index int8 plane, nnz scalar).
    """
    if interpret is None:
        interpret = not _on_tpu()
    qt_plane = _ref.qtable_plane(level, *x.shape)
    return _k.quant_pack_plane_pallas(
        x, fmin, fmax, qt_plane, bits=bits, interpret=interpret
    )
