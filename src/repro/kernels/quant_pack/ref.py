"""Pure-jnp oracle for the paper-exact quantize+bitmap Pallas kernel.

Semantics: given a plane (R, C) of DCT coefficients (R, C multiples of 8),
global range (fmin, fmax) and a quantization level, apply the paper's two-step
quantization (Eq. 7-8 with the JPEG level shift) per aligned 8x8 block and emit
the quantized plane, the 1-bit index plane, and the total non-zero count.
"""
import jax.numpy as jnp

from repro.core import quantize as quant_lib

BLOCK = 8


def qtable_plane(level: int, r: int, c: int) -> jnp.ndarray:
    return quant_lib.qtable_plane(level, r, c)


def quant_pack_plane(x: jnp.ndarray, fmin, fmax, level: int, bits: int = 8):
    params = quant_lib.QuantParams(jnp.float32(fmin), jnp.float32(fmax), bits)
    q1 = quant_lib.quantize_minmax(x.astype(jnp.float32), params)
    qt = qtable_plane(level, *x.shape)
    q2 = jnp.round((q1 - params.zero_point) / qt)
    index = (q2 != 0).astype(jnp.int8)
    nnz = jnp.sum(index.astype(jnp.int32))
    return q2.astype(jnp.int32), index, nnz
