import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and derive the roofline terms (assignment §MULTI-POD DRY-RUN).

The two lines above MUST stay the first statements in this file — jax locks
the device count at first init, and the production meshes need 512 host
placeholder devices. Nothing here allocates device memory: states and inputs
are ShapeDtypeStructs, compile is ahead-of-time only.

Usage:
  python -m repro.launch.dryrun                          # full sweep
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k
  python -m repro.launch.dryrun --mesh multi_pod         # only 2x16x16
  python -m repro.launch.dryrun --variant compressed     # paper-technique on

Artifacts: one JSON per cell under benchmarks/artifacts/dryrun/.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import api as model_api
from repro.models.api import SkippedShape
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh
from repro.roofline import analysis as roofline
from repro.serve import engine as serve_engine
from repro.train import step as train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts/dryrun")


def _microbatches_for(cfg, mesh, global_batch: int = 256) -> int:
    """Grad-accumulation depth: deep enough that a microbatch's activations
    fit HBM, shallow enough that every DP shard still gets >= 1 row (a
    microbatch smaller than the DP width pads half the fleet with zeros —
    measured as useful_flop_ratio 0.12 vs 0.35 on deepseek multi-pod)."""
    from repro.parallel.mesh import dp_size

    # activation footprint scales with ACTIVE params (MoE activations are
    # top-k sized, not total-expert sized)
    active = cfg.param_counts()["active"]
    if active > 2e11:
        n = 16
    elif active > 5e10:
        n = 8
    elif active > 5e9:
        n = 4
    else:
        n = 1
    return max(1, min(n, global_batch // max(dp_size(mesh), 1)))


def _to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def build_cell(api, mesh, shape_name: str, variant: str):
    """Returns (fn, example_args, in_shardings) ready for jit().lower()."""
    cfg = api.cfg
    kind = SHAPES[shape_name][2]
    axes = tuple(mesh.axis_names)

    if kind == "train":
        tc = train_step.TrainConfig(
            microbatches=_microbatches_for(cfg, mesh),
            remat="compressed" if variant == "compressed" else "full",
            grad_compress=(variant == "compressed" and "pod" in axes),
        )
        state = jax.eval_shape(lambda: train_step.init_train_state(api, tc))
        sspec = train_step.state_specs(state, mesh, tc)
        batch = api.input_specs(shape_name)
        bspec = train_step.batch_specs(batch, mesh)
        fn = train_step.make_train_step(api, mesh, tc)
        return (
            fn,
            (state, batch),
            (_to_shardings(mesh, sspec), _to_shardings(mesh, bspec)),
            (_to_shardings(mesh, sspec), None),
        )

    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    pspec = sh.param_specs(params, mesh, fsdp=True)

    if kind == "prefill":
        batch = api.input_specs(shape_name)
        bspec = train_step.batch_specs(batch, mesh)

        def fwd(p, b):
            return api.forward(p, b, remat="none")

        return (
            fwd,
            (params, batch),
            (_to_shardings(mesh, pspec), _to_shardings(mesh, bspec)),
            None,
        )

    # decode
    specs = api.input_specs(shape_name)
    token, cache, pos = specs["token"], specs["cache"], specs["pos"]
    cspec = sh.cache_specs(cache, cfg, mesh)
    tspec = sh.data_batch_spec(axes, 1, dim0=token.shape[0], mesh=mesh)

    if variant == "compressed" and cfg.attn_type == "gqa" \
            and cfg.vec_pos_decode \
            and cfg.resolved_head_dim % 8 == 0:
        # KVCompress: the int8 DCT store replaces the raw cache
        seq, batch_size, _ = SHAPES[shape_name]
        cache = jax.eval_shape(
            lambda: serve_engine.init_compressed_cache(cfg, batch_size, seq)
        )
        cache_dict = {
            "packed_k": cache.packed_k, "scale_k": cache.scale_k,
            "packed_v": cache.packed_v, "scale_v": cache.scale_v,
            "tail_k": cache.tail_k, "tail_v": cache.tail_v,
        }
        cdspec = sh.cache_specs(cache_dict, cfg, mesh)

        def dec(p, t, c, q):
            import repro.core.kv_cache as kvc
            cc = kvc.CompressedKVCache.from_arrays(
                c["packed_k"], c["scale_k"], c["packed_v"], c["scale_v"],
                c["tail_k"], c["tail_v"], keep=4,
            )
            logits, nc = serve_engine.decode_step_compressed(p, t, cc, q, cfg)
            return logits, {
                "packed_k": nc.packed_k, "scale_k": nc.scale_k,
                "packed_v": nc.packed_v, "scale_v": nc.scale_v,
                "tail_k": nc.tail_k, "tail_v": nc.tail_v,
            }

        return (
            dec,
            (params, token, cache_dict, pos),
            (
                _to_shardings(mesh, pspec),
                NamedSharding(mesh, tspec),
                _to_shardings(mesh, cdspec),
                NamedSharding(mesh, P()),
            ),
            None,
        )

    if variant == "unrolled":
        def dec(p, t, c, q):
            return api.decode_step(p, t, c, q, unroll=True)
    else:
        def dec(p, t, c, q):
            return api.decode_step(p, t, c, q)

    return (
        dec,
        (params, token, cache, pos),
        (
            _to_shardings(mesh, pspec),
            NamedSharding(mesh, tspec),
            _to_shardings(mesh, cspec),
            NamedSharding(mesh, P()),
        ),
        None,
    )


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, variant: str,
             art_dir: str) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch_id}/{shape_name}/{mesh_name}/{variant}"
    cfg = get_config(arch_id)
    ok, why = cfg.shape_supported(shape_name)
    if not ok:
        print(f"[skip] {cell}: {why}")
        rec = {"cell": cell, "status": "skipped", "reason": why}
        os.makedirs(art_dir, exist_ok=True)
        fname = f"{arch_id}__{shape_name}__{mesh_name}__{variant}.json"
        with open(os.path.join(art_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    api = model_api.build(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh = build_cell(api, mesh, shape_name, variant)
        with mesh_lib.use_mesh(mesh):
            jit_kw = {"in_shardings": in_sh}
            if out_sh is not None:
                jit_kw["out_shardings"] = out_sh
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        r = roofline.from_compiled(arch_id, shape_name, mesh_name,
                                   int(np.prod(mesh.devices.shape)), compiled, cfg)
        rec = {
            "cell": cell, "status": "ok", "variant": variant,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            **r.to_dict(),
        }
        print(f"[ok]   {roofline.format_row(r)}  (compile {t_compile:.0f}s)")
    except SkippedShape as e:
        rec = {"cell": cell, "status": "skipped", "reason": str(e)}
        print(f"[skip] {cell}: {e}")
    except Exception as e:
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {cell}: {type(e).__name__}: {str(e)[:200]}")
    os.makedirs(art_dir, exist_ok=True)
    fname = f"{arch_id}__{shape_name}__{mesh_name}__{variant}.json"
    with open(os.path.join(art_dir, fname), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi_pod", "both"])
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "compressed", "unrolled"])
    ap.add_argument("--art-dir", default=os.path.normpath(ART_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi_pod": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.variant, args.art_dir))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED "
          f"of {len(results)} cells ==")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
