"""Production mesh factory — re-export (assignment §MULTI-POD DRY-RUN).

The one mesh factory lives in `repro.parallel.mesh`; this module used to
carry a verbatim copy and now just re-exports it for the dry-run / HLO
tooling import path.  Still a FUNCTION, not a module-level constant:
importing this module must never touch jax device state (the dry-run sets
XLA_FLAGS before first jax init), which the re-export preserves.
"""
from __future__ import annotations

from repro.parallel.mesh import make_production_mesh  # noqa: F401
