"""Serving driver: continuous-batching generation with raw or DCT-compressed
KV cache, optionally sharded over a (data x model) device mesh.

    python -m repro.launch.serve --arch yi_6b --reduced --requests 8 \
        --kv-compress --kv-keep 6

    # 4-way slot-pool sharding (needs 4 devices, e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=4):
    python -m repro.launch.serve --arch yi_6b --reduced --kv-compress \
        --mesh 4x1

The engine is a slot scheduler: requests with different prompt lengths and
budgets stream through a fixed pool of batch slots, each slot at its own
position over the compressed store. `--scheduler static` restores the
lock-step wave baseline. `--mesh DATAxMODEL` places batch slots (and every
compressed-pool plane) on `data` and attention heads on `model`; params are
device_put with the train-path `param_specs` BEFORE the engine builds, so
multi-device serving never silently replicates weights. Reports tokens/s,
slot utilization, and the analytic KV-cache HBM footprint both ways — the
serving analogue of the paper's Table II bandwidth saving.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import plan as plan_lib
from repro.configs.base import ARCH_IDS, get_config
from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh
from repro.serve import engine as E


def kv_bytes_per_token(cfg, compressed: bool,
                       plan: plan_lib.CompressionPlan) -> float:
    if not compressed:
        return plan_lib.raw_kv_bytes_per_token(cfg)
    return plan.kv_bytes_per_token(cfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--kv-compress", action="store_true")
    ap.add_argument("--kv-keep", type=int, default=4,
                    help="legacy uniform keep (shim for --kv-plan)")
    ap.add_argument("--kv-plan", default=None,
                    help="per-layer CompressionPlan spec, e.g. "
                         "'0-3:keep=6,4-:keep=3' (overrides --kv-keep)")
    ap.add_argument("--kv-budget-mb", type=float, default=None,
                    help="solve the plan from a KV byte budget instead "
                         "(CompressionPlan.from_budget; overrides --kv-plan)")
    ap.add_argument("--kv-codec", default=None,
                    help="codec family for every layer (dct, bitplane, asc); "
                         "overrides any codec= tokens in --kv-plan. Mixed "
                         "families go in the spec: '0-3:keep=6,"
                         "4-:keep=4+codec=bitplane'")
    ap.add_argument("--kv-pool-pages", type=int, default=None,
                    help="paged KV pool: shared page count (one page = one "
                         "8-token block group across all layers); decouples "
                         "slot count from max_seq provisioning")
    ap.add_argument("--kv-page-budget-mb", type=float, default=None,
                    help="paged KV pool sized from a byte budget instead "
                         "(pages = budget // per-plan page bytes)")
    ap.add_argument("--host-pool-pages", type=int, default=None,
                    help="tiered pool: host-RAM page count behind the device "
                         "pool; cold slots' compressed pages spill there "
                         "under page pressure and stream back before the "
                         "slot's next attend")
    ap.add_argument("--host-pool-mb", type=float, default=None,
                    help="size the host tier from a byte budget instead "
                         "(pages = budget // per-plan page bytes)")
    ap.add_argument("--tier-watermarks", default=None,
                    help="LOW,HIGH free-page fractions of the device pool "
                         "(default 0.25,0.5): queued demand with free pages "
                         "under LOW evicts cold slots until HIGH is free")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="copy-on-write prompt-prefix sharing: identical "
                         "prompt prefixes map the same physical pages "
                         "(content-hashed, verified bitwise on device); "
                         "admission reserves only the unshared suffix")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prefill-buckets", default=None,
                    help="comma-separated prompt-length buckets (multiples "
                         "of 8, <= max-seq) the engine compiles prefill at; "
                         "admission rounds prompts UP to the ladder. Default: "
                         "powers-of-two multiples of 8 capped at max-seq")
    ap.add_argument("--decode-buckets", default=None,
                    help="paged pool only: comma-separated context-length "
                         "buckets (multiples of 8, <= max-seq) the decode "
                         "step is compiled at; each step attends a static "
                         "bucket//8-entry block-table slice covering the "
                         "deepest live slot. 'off' pins the single "
                         "full-capacity step. Default: powers-of-two "
                         "multiples of 8 capped at max-seq")
    ap.add_argument("--decode-tile-pages", type=int, default=8,
                    help="pages the paged attend kernel gathers (and scores "
                         "as one (G*8, head_dim) tile) per grid step; "
                         "shrunk to a divisor of each bucket's block count")
    ap.add_argument("--aot-warmup", action="store_true",
                    help="compile the whole prefill ladder + decode step at "
                         "engine build, so no XLA compile happens under "
                         "traffic (time reported as warmup_s)")
    ap.add_argument("--no-packed-admission", action="store_true",
                    help="admit one prompt per prefill call instead of "
                         "packing all free slots into one bucketed call")
    ap.add_argument("--sync-host", action="store_true",
                    help="disable the one-step-deep async pipeline: read "
                         "each decode step's tokens before dispatching the "
                         "next, and run bookkeeping inline")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL serve mesh, e.g. 4x1 or 2x2 (batch "
                         "slots shard on data, attention heads on model); "
                         "default: single-device")
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--vary-lengths", action="store_true",
                    help="draw prompt lengths/budgets per request (shows the "
                         "slot scheduler retiring and re-admitting)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = model_api.build(args.arch, cfg)
    if api.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode path (encoder-decoder cap)")

    params = api.init(jax.random.PRNGKey(0))
    mesh = mesh_lib.make_serve_mesh(args.mesh)
    if mesh is not None:
        # place loaded params per the param rules BEFORE the engine builds:
        # `init` leaves them wherever device 0 is, and feeding that into a
        # multi-device jit would silently replicate (or re-transfer) every
        # call. Serving never FSDP-shards weights (fsdp=False): TP on
        # `model`, replicated across `data`.
        params = jax.device_put(
            params, sh.param_shardings(params, mesh, fsdp=False))
    if args.kv_budget_mb is not None:
        plan = plan_lib.CompressionPlan.from_budget(
            cfg, args.max_seq, args.kv_budget_mb * 1e6, batch=args.batch)
        if args.kv_codec is not None:
            plan = plan.with_codec(args.kv_codec)
    else:
        plan = plan_lib.as_plan(args.kv_plan, keep=args.kv_keep,
                                codec=args.kv_codec)
    buckets = tuple(int(b) for b in args.prefill_buckets.split(",")) \
        if args.prefill_buckets else None
    if args.decode_buckets == "off":
        dec_buckets = False
    elif args.decode_buckets:
        dec_buckets = tuple(int(b) for b in args.decode_buckets.split(","))
    else:
        dec_buckets = None
    sc = E.ServeConfig(
        max_seq=args.max_seq, max_new_tokens=args.max_new,
        kv_compress=args.kv_compress, plan=plan,
        temperature=args.temperature, mesh=mesh,
        pool_pages=args.kv_pool_pages, page_budget_mb=args.kv_page_budget_mb,
        host_pool_pages=args.host_pool_pages, host_pool_mb=args.host_pool_mb,
        tier_watermarks=tuple(float(w) for w in args.tier_watermarks.split(","))
        if args.tier_watermarks else (0.25, 0.5),
        prefix_sharing=args.prefix_sharing,
        prefill_buckets=buckets, aot_warmup=args.aot_warmup,
        packed_admission=not args.no_packed_admission,
        async_host=not args.sync_host,
        decode_buckets=dec_buckets, decode_tile_pages=args.decode_tile_pages,
    )
    eng = E.Engine(api, params, sc, batch=args.batch, scheduler=args.scheduler)

    rng = np.random.default_rng(0)
    requests = []
    for i in range(args.requests):
        plen = args.prompt_len
        max_new = args.max_new
        if args.vary_lengths:
            plen = int(rng.integers(max(1, plen // 4), plen + 1))
            max_new = int(rng.integers(max(1, max_new // 4), max_new + 1))
        requests.append(E.Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=max_new))
    done = eng.generate(requests)

    st = eng.stats
    # first token per request is sampled from prefill logits — exclude it
    # from the decode-loop rate
    dec_tok = st["tokens_out"] - st["requests"]
    dec_tps = dec_tok / st["decode_s"] if st["steps"] else 0.0
    print(f"arch={cfg.name} kv_compress={args.kv_compress} "
          f"plan={plan.to_spec()} scheduler={eng.scheduler} "
          f"mesh={mesh_lib.mesh_desc(mesh)}")
    print(f"requests={st['requests']} decode_steps={st['steps']} "
          f"tokens_out={st['tokens_out']} decode_tok/s={dec_tps:.1f} "
          f"slot_util={eng.slot_utilization():.2f}")
    print(f"time split: warmup_s={st['warmup_s']:.2f} "
          f"prefill_s={st['prefill_s']:.2f} decode_s={st['decode_s']:.2f} "
          f"host_s={st['host_s']:.2f}")
    if eng.scheduler == "continuous":
        lat = eng.latency_stats()
        print(f"latency: ttft p50={lat['ttft_p50_s']*1e3:.1f}ms "
              f"p99={lat['ttft_p99_s']*1e3:.1f}ms | "
              f"itl p50={lat['itl_p50_s']*1e3:.1f}ms "
              f"p99={lat['itl_p99_s']*1e3:.1f}ms "
              f"(ladder={list(eng.ladder.buckets)})")
    raw_b = kv_bytes_per_token(cfg, False, plan)
    cmp_b = kv_bytes_per_token(cfg, True, plan)
    print(f"KV bytes/token: raw {raw_b:.0f} vs compressed {cmp_b:.0f} "
          f"({raw_b / cmp_b:.1f}x) -> at {args.max_seq} ctx x batch "
          f"{args.batch}: {raw_b*args.max_seq*args.batch/1e6:.1f} MB vs "
          f"{cmp_b*args.max_seq*args.batch/1e6:.1f} MB")
    if mesh is not None:
        ps = eng.kv_pool_stats()
        print(f"KV pool per device: {ps['kv_bytes_per_device']/1e6:.2f} MB "
              f"of {ps['kv_pool_bytes']/1e6:.2f} MB total "
              f"across {mesh.devices.size} devices")
    if eng.paged:
        ps = eng.kv_pool_stats()
        print(f"paged pool: {ps['pool_pages']} pages x {ps['page_bytes']} B "
              f"(peak in use {ps['peak_pages_in_use']}), "
              f"peak live slots {eng.stats['peak_live_slots']}, "
              f"admissions blocked on pages "
              f"{eng.stats['admit_blocked_on_pages']}, "
              f"{ps['slots_per_gb']:.0f} slots/GB")
        mean_bucket = st["decode_bucket_tokens"] / max(st["steps"], 1)
        print(f"decode ladder {list(eng.decode_ladder.buckets)}: mean bucket "
              f"{mean_bucket:.1f} of {args.max_seq} max-seq tokens/step")
        if sc.tiered:
            print(f"host tier: {ps['host_pool_pages']} pages "
                  f"({ps['host_pool_bytes']/1e6:.2f} MB), "
                  f"spilled {ps['pages_spilled']} / restored "
                  f"{ps['pages_restored']} pages, parked "
                  f"{ps['slots_parked']} / resumed {ps['slots_resumed']} "
                  f"slots, {ps['pages_host_in_use']} host pages in use")
        if sc.prefix_sharing:
            print(f"prefix sharing: {ps['prefix_shared_blocks']} blocks "
                  f"admitted by reference, {ps['shared_physical_pages']} "
                  f"physical pages currently shared, "
                  f"{ps['prefix_demotions']} collision demotions")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens[:12]}{'...' if len(r.out_tokens) > 12 else ''}")
    return done


if __name__ == "__main__":
    main()
