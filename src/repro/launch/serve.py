"""Serving driver: batched generation with raw or DCT-compressed KV cache.

    python -m repro.launch.serve --arch yi_6b --reduced --requests 8 \
        --kv-compress --kv-keep 6

Reports tokens/s and the analytic KV-cache HBM footprint both ways — the
serving analogue of the paper's Table II bandwidth saving.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import api as model_api
from repro.serve import engine as E


def kv_bytes_per_token(cfg, compressed: bool, keep: int) -> float:
    hd = cfg.resolved_head_dim
    raw = 2 * cfg.n_kv_heads * hd * 2  # k+v bf16
    if not compressed:
        return cfg.n_layers * raw
    per_block = cfg.n_kv_heads * (hd // 8) * (keep * keep + 4)
    return cfg.n_layers * 2 * per_block / 8


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--kv-compress", action="store_true")
    ap.add_argument("--kv-keep", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = model_api.build(args.arch, cfg)
    if api.decode_step is None:
        raise SystemExit(f"{args.arch} has no decode path (encoder-decoder cap)")

    params = api.init(jax.random.PRNGKey(0))
    sc = E.ServeConfig(
        max_seq=args.max_seq, max_new_tokens=args.max_new,
        kv_compress=args.kv_compress, kv_keep=args.kv_keep,
        temperature=args.temperature,
    )
    eng = E.Engine(api, params, sc, batch=args.batch)

    rng = np.random.default_rng(0)
    done = []
    pending = [
        E.Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                  max_new=args.max_new)
        for i in range(args.requests)
    ]
    while pending:
        wave, pending = pending[:args.batch], pending[args.batch:]
        done += eng.generate(wave)

    st = eng.stats
    dec_tps = st["steps"] * args.batch / max(st["decode_s"], 1e-9)
    print(f"arch={cfg.name} kv_compress={args.kv_compress} keep={args.kv_keep}")
    print(f"requests={st['requests']} decode_steps={st['steps']} "
          f"decode_tok/s={dec_tps:.1f} prefill_s={st['prefill_s']:.2f}")
    raw_b = kv_bytes_per_token(cfg, False, args.kv_keep)
    cmp_b = kv_bytes_per_token(cfg, True, args.kv_keep)
    print(f"KV bytes/token: raw {raw_b:.0f} vs compressed {cmp_b:.0f} "
          f"({raw_b / cmp_b:.1f}x) -> at {args.max_seq} ctx x batch "
          f"{args.batch}: {raw_b*args.max_seq*args.batch/1e6:.1f} MB vs "
          f"{cmp_b*args.max_seq*args.batch/1e6:.1f} MB")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.out_tokens[:12]}{'...' if len(r.out_tokens) > 12 else ''}")
    return done


if __name__ == "__main__":
    main()
