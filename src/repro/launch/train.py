"""End-to-end training driver.

    python -m repro.launch.train --arch qwen2_0_5b --steps 300 \
        --reduced --seq 256 --batch 32 --remat compressed

Runs the full production stack on whatever devices exist: sharded state,
microbatched train step, ActCompress remat, checkpoint/auto-resume,
preemption guard, straggler monitor. `--reduced` scales the architecture to
a CPU-sized model so a few hundred steps run here (examples/ uses it);
omit it on real hardware.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import store
from repro.configs.base import ARCH_IDS, get_config
from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig
from repro.parallel import mesh as mesh_lib
from repro.runtime import fault
from repro.train import step as train_step


def make_batch_fn(api, seq: int, batch: int):
    cfg = api.cfg
    ts = TokenStream(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)

    def batches(step: int):
        b = ts.batch(step)
        out = {"tokens": jnp.asarray(b["tokens"]), "labels": jnp.asarray(b["labels"])}
        if cfg.is_encoder_decoder:
            rng = np.random.default_rng(step)
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.encoder_seq_len or 16, cfg.d_model)),
                jnp.bfloat16,
            )
        elif cfg.frontend == "vision_stub":
            rng = np.random.default_rng(step)
            pf = min(cfg.frontend_tokens or 16, 16)
            out["patches"] = jnp.asarray(
                rng.standard_normal((batch, pf, cfg.d_model)), jnp.bfloat16
            )
            out["labels"] = jnp.concatenate(
                [jnp.full((batch, pf), -1, jnp.int32), out["labels"]], axis=1
            )
        return out

    return batches


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--remat", default="compressed",
                    choices=["none", "full", "compressed"])
    ap.add_argument("--compress-plan", default=None,
                    help="per-layer CompressionPlan spec for ActCompress, "
                         "e.g. '0-3:keep=6,4-:keep=3' (overrides "
                         "--compress-keep; see repro.codec.plan)")
    ap.add_argument("--compress-keep", "--compress_keep", type=int, default=4,
                    help="legacy uniform keep (shim for --compress-plan)")
    ap.add_argument("--compress-codec", default=None,
                    help="codec family for every layer (dct, bitplane, asc); "
                         "overrides codec= tokens in --compress-plan")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        # widen over the smoke size so the run exercises real matmuls
        cfg = dataclasses.replace(cfg, d_model=256, n_heads=8, head_dim=32,
                                  d_ff=1024, n_layers=min(cfg.n_layers, 8))
    api = model_api.build(args.arch, cfg)

    n_dev = len(jax.devices())
    mp = args.model_par
    mesh = jax.make_mesh((max(n_dev // mp, 1), mp), ("data", "model"))
    tc = train_step.TrainConfig(
        microbatches=args.microbatches,
        remat=args.remat,
        plan=args.compress_plan,           # None => uniform(compress_keep)
        compress_keep=args.compress_keep,
        codec=args.compress_codec,
        grad_compress=args.grad_compress,
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps),
    )

    state = train_step.init_train_state(api, tc)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={n_dev} "
          f"mesh={dict(mesh.shape)} remat={tc.remat}")

    ckpt_root = os.path.join(args.ckpt_dir, cfg.name)
    start = store.latest_step(ckpt_root)
    if start is not None:
        state, start = store.restore(ckpt_root, state)
        print(f"resumed from step {start}")
    else:
        start = 0

    batches = make_batch_fn(api, args.seq, args.batch)
    with mesh_lib.use_mesh(mesh):
        step_fn = train_step.jit_train_step(api, mesh, tc, state, batches(0))

        monitor = fault.StragglerMonitor()
        losses = []
        t_prev = time.perf_counter()

        def logged_step(st, b):
            nonlocal t_prev
            st, metrics = step_fn(st, b)
            losses.append(float(metrics["loss"]))
            n = len(losses)
            if n % args.log_every == 0:
                dt = (time.perf_counter() - t_prev) / args.log_every
                t_prev = time.perf_counter()
                print(f"step {start + n:5d} loss {losses[-1]:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:6.0f} ms/step")
            return st, metrics

        state, last, reason = fault.train_loop(
            logged_step, state, batches,
            start_step=start, num_steps=args.steps,
            save_every=args.save_every,
            save_fn=lambda s, st: store.save_async(ckpt_root, s, st),
            monitor=monitor,
        )
    store.wait_pending()
    print(f"exit={reason} at step {last}; first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
