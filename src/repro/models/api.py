"""Unified model API: one façade over all 10 architecture families.

`build(arch_id)` returns a `ModelAPI` whose methods are pure functions fit
for jit/pjit: init, forward (train/prefill), loss, init_cache, decode_step,
and `input_specs(shape_name)` — the ShapeDtypeStruct stand-ins the multi-pod
dry-run lowers against (no allocation).

Shape semantics (assignment):
  train_4k / prefill_32k lower the full-sequence forward;
  decode_32k / long_500k lower `serve_step` — one new token against a KV
  cache (or recurrent state) of seq_len.

Modality stubs: [vlm] patches (B, P, D) and [audio] frames (B, T, D) arrive
as precomputed embeddings. Whisper's decoder is architecturally capped at 448
tokens, so its "seq" shapes are reinterpreted as (enc 1500, dec<=448) and its
decode shapes are skipped (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import plan as plan_lib
from repro.configs.base import SHAPES, ArchConfig, get_config
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as T

Params = dict[str, Any]
Batch = dict[str, jax.Array]


def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, dict]:
    """Masked softmax CE. logits (B, S, V) f32; labels (B, S) with -1 = pad."""
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    per_tok = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = per_tok.sum() / denom
    return loss, {"loss": loss, "tokens": denom}


@dataclass(frozen=True)
class ModelAPI:
    arch_id: str
    cfg: ArchConfig
    init: Callable[..., Params]
    forward: Callable[..., jax.Array]           # (params, batch, **kw) -> logits
    loss: Callable[..., tuple]                  # (params, batch, **kw) -> (loss, metrics)
    init_cache: Callable[..., Params] | None    # (batch, max_seq, dtype) -> cache
    decode_step: Callable[..., tuple] | None    # (params, token, cache, pos) -> (logits, cache)

    # ---------------- input specs (dry-run stand-ins) --------------------
    def shape_plan(self, shape_name: str) -> dict:
        """Resolve a named shape to this arch's concrete dims."""
        seq, batch, kind = SHAPES[shape_name]
        cfg = self.cfg
        plan = {"kind": kind, "batch": batch, "seq": seq}
        if cfg.is_encoder_decoder:  # whisper: (enc frames, dec tokens<=cap)
            plan["enc_len"] = cfg.encoder_seq_len
            plan["seq"] = min(seq, cfg.max_seq_len or seq)
        if cfg.frontend == "vision_stub":
            plan["prefix"] = min(cfg.frontend_tokens, max(seq - 64, 0))
            plan["text"] = seq - plan["prefix"]
        return plan

    def input_specs(self, shape_name: str, dtype=jnp.bfloat16) -> Batch:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        ok, why = self.cfg.shape_supported(shape_name)
        if not ok:
            raise SkippedShape(why)
        p = self.shape_plan(shape_name)
        b, s, kind = p["batch"], p["seq"], p["kind"]
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        cfg = self.cfg
        if kind in ("train", "prefill"):
            specs: Batch = {}
            if cfg.is_encoder_decoder:
                specs["frames"] = sds((b, p["enc_len"], cfg.d_model), dtype)
                specs["tokens"] = sds((b, s), i32)
                if kind == "train":
                    specs["labels"] = sds((b, s), i32)
            elif cfg.frontend == "vision_stub":
                specs["patches"] = sds((b, p["prefix"], cfg.d_model), dtype)
                specs["tokens"] = sds((b, p["text"]), i32)
                if kind == "train":
                    specs["labels"] = sds((b, s), i32)  # full seq, prefix masked
            else:
                specs["tokens"] = sds((b, s), i32)
                if kind == "train":
                    specs["labels"] = sds((b, s), i32)
            return specs
        # decode: one token + cache of length s; pos is PER SLOT (B,) — the
        # continuous-batching serve path (scalars still broadcast for the
        # recurrent families' scalar step index)
        cache = jax.eval_shape(lambda: self.init_cache(b, s, dtype))
        pos_shape = (b,) if self.cfg.vec_pos_decode else ()
        return {
            "token": sds((b,), i32),
            "cache": cache,
            "pos": sds(pos_shape, i32),
        }


class SkippedShape(Exception):
    """Raised for (arch, shape) cells excluded by DESIGN.md §4."""


# ---------------------------------------------------------------------------
# Central compression-kwarg handling (one sanctioned `plan=` argument)
# ---------------------------------------------------------------------------

# families whose forward routes through T.forward and supports ActCompress
_PLAN_FAMILIES = ("dense", "moe", "vlm")


def _with_plan_handling(api: ModelAPI) -> ModelAPI:
    """Normalize compression kwargs once, centrally, for every family.

    `plan=` is the sanctioned argument; `compress_keep=`/`codec_backend=`
    are the legacy scalar shims (compress_keep=k == CompressionPlan.uniform(k)).
    Families that compress (transformers) get the resolved plan; families
    that don't (whisper/zamba/rwkv) simply never see the kwargs — this
    replaces the per-adapter kwarg filtering the adapters used to duplicate.
    """
    supports_plan = api.cfg.family in _PLAN_FAMILIES

    def wrap(fn):
        def wrapped(params, batch, *, plan=None, compress_keep=None,
                    codec_backend=None, **kw):
            if plan is not None or compress_keep is not None \
                    or codec_backend is not None:
                if supports_plan:
                    kw["plan"] = plan_lib.as_plan(plan, keep=compress_keep,
                                                  backend=codec_backend)
            return fn(params, batch, **kw)

        return wrapped

    return dataclasses.replace(api, forward=wrap(api.forward), loss=wrap(api.loss))


# ---------------------------------------------------------------------------
# Family adapters
# ---------------------------------------------------------------------------

def _lm_api(arch_id: str, cfg: ArchConfig) -> ModelAPI:
    def forward(params, batch, **kw):
        return T.forward(params, batch["tokens"], cfg,
                         prefix_embeds=batch.get("patches"), **kw)

    def loss(params, batch, **kw):
        logits = forward(params, batch, **kw)
        return cross_entropy(logits, batch["labels"])

    def init_cache(batch, max_seq, dtype=jnp.bfloat16):
        return T.init_kv_cache(cfg, batch, max_seq, dtype)

    def decode_step(params, token, cache, pos, **kw):
        return T.decode_step(params, token, cache, pos, cfg, **kw)

    return ModelAPI(arch_id, cfg, lambda key, dtype=jnp.bfloat16: T.init_lm(key, cfg, dtype),
                    forward, loss, init_cache, decode_step)


def _vlm_loss_api(arch_id: str, cfg: ArchConfig) -> ModelAPI:
    base = _lm_api(arch_id, cfg)

    def loss(params, batch, **kw):
        logits = base.forward(params, batch, **kw)  # (B, P+T, V)
        return cross_entropy(logits, batch["labels"])

    return ModelAPI(arch_id, cfg, base.init, base.forward, loss,
                    base.init_cache, base.decode_step)


def _whisper_api(arch_id: str, cfg: ArchConfig) -> ModelAPI:
    def init(key, dtype=jnp.bfloat16):
        return T.init_encdec(key, cfg, dtype)

    def forward(params, batch, **kw):
        return T.encdec_forward(params, batch["frames"], batch["tokens"], cfg, **kw)

    def loss(params, batch, **kw):
        logits = forward(params, batch, **kw)
        return cross_entropy(logits, batch["labels"])

    return ModelAPI(arch_id, cfg, init, forward, loss, None, None)


def _zamba_api(arch_id: str, cfg: ArchConfig) -> ModelAPI:
    def forward(params, batch, **kw):
        return ssm_lib.zamba_forward(params, batch["tokens"], cfg, **kw)

    def loss(params, batch, **kw):
        logits = forward(params, batch, **kw)
        return cross_entropy(logits, batch["labels"])

    def init_cache(batch, max_seq, dtype=jnp.bfloat16):
        return ssm_lib.init_zamba_cache(cfg, batch, max_seq, dtype)

    def decode_step(params, token, cache, pos, **kw):
        return ssm_lib.zamba_decode_step(params, token, cache, pos, cfg, **kw)

    return ModelAPI(arch_id, cfg, lambda key, dtype=jnp.bfloat16: ssm_lib.init_zamba(key, cfg, dtype),
                    forward, loss, init_cache, decode_step)


def _rwkv_api(arch_id: str, cfg: ArchConfig) -> ModelAPI:
    def forward(params, batch, **kw):
        return rwkv_lib.rwkv_forward(params, batch["tokens"], cfg, **kw)

    def loss(params, batch, **kw):
        logits = forward(params, batch, **kw)
        return cross_entropy(logits, batch["labels"])

    def init_cache(batch, max_seq, dtype=jnp.bfloat16):
        # attention-free: the recurrent state IS the cache; max_seq is vacuous
        return rwkv_lib.init_rwkv_cache(cfg, batch, dtype)

    def decode_step(params, token, cache, pos, **kw):
        return rwkv_lib.rwkv_decode_step(params, token, cache, pos, cfg)

    return ModelAPI(arch_id, cfg, lambda key, dtype=jnp.bfloat16: rwkv_lib.init_rwkv(key, cfg, dtype),
                    forward, loss, init_cache, decode_step)


def build(arch_id: str, cfg: ArchConfig | None = None) -> ModelAPI:
    arch_id = arch_id.replace("-", "_")
    cfg = cfg or get_config(arch_id)
    if cfg.family in ("dense", "moe"):
        api = _lm_api(arch_id, cfg)
    elif cfg.family == "vlm":
        api = _vlm_loss_api(arch_id, cfg)
    elif cfg.family == "audio":
        api = _whisper_api(arch_id, cfg)
    elif cfg.family == "hybrid":
        api = _zamba_api(arch_id, cfg)
    elif cfg.family == "ssm":
        api = _rwkv_api(arch_id, cfg)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return _with_plan_handling(api)


def build_reduced(arch_id: str) -> ModelAPI:
    """Smoke-test sized API of the same family."""
    return build(arch_id, get_config(arch_id).reduced())
