"""Pure-JAX CNNs for the paper-faithful reproduction (paper §VI benchmarks).

Networks: VGG-16-BN, ResNet-50, MobileNet-v1, MobileNet-v2, a YOLO-v3
(Darknet-53) backbone, and a tiny trainable CNN for the accuracy-loss
experiment.  Each network exposes the paper's *fusion layer* boundaries
(conv [+BN] [+act] [+pool] groups); after every fusion layer the interlayer
feature map may be compressed with a per-layer `CompressionPolicy`, exactly
where the paper's DCT module sits in the accelerator pipeline (Fig. 6).

Layout: NHWC activations, HWIO weights.  Compression operates per (N, C)
plane on the (H, W) spatial grid in 8x8 blocks, as in the paper.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import codec as codec_lib
from repro.codec import plan as plan_lib
from repro.core import compressor

Params = dict


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def conv_init(key, kh, kw, cin, cout, depthwise=False):
    fan_in = kh * kw * (1 if depthwise else cin)
    std = np.sqrt(2.0 / fan_in)
    shape = (kh, kw, 1 if depthwise else cin, cout)
    return {"w": jax.random.normal(key, shape, jnp.float32) * std}


def conv(params, x, stride=1, depthwise=False, groups=1):
    dn = jax.lax.conv_dimension_numbers(x.shape, params["w"].shape, ("NHWC", "HWIO", "NHWC"))
    feature_group_count = x.shape[-1] if depthwise else groups
    return jax.lax.conv_general_dilated(
        x,
        params["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=dn,
        feature_group_count=feature_group_count,
    )


def bn_init(key, c):
    k1, _ = jax.random.split(key)
    # inference-mode statistics: unit variance, small random mean/gamma jitter
    return {
        "gamma": jnp.ones((c,)) + 0.1 * jax.random.normal(k1, (c,)),
        "beta": jnp.zeros((c,)),
        "mean": jnp.zeros((c,)),
        "var": jnp.ones((c,)),
    }


def bn(params, x, eps=1e-5):
    inv = params["gamma"] / jnp.sqrt(params["var"] + eps)
    return x * inv + (params["beta"] - params["mean"] * inv)


def relu(x):
    return jnp.maximum(x, 0)


def leaky_relu(x, alpha=0.1):
    return jnp.where(x >= 0, x, alpha * x)


def relu6(x):
    return jnp.clip(x, 0, 6)


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "SAME"
    )


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


# --------------------------------------------------------------------------
# Fusion-layer compression hook (the paper's DCT module insertion point)
# --------------------------------------------------------------------------

@dataclass
class CompressionSchedule:
    """Which fusion layers to compress and at what level (paper §III-B).

    A thin alias over `repro.codec.plan.CompressionPlan`: the per-fusion-
    layer policy lives in `plan` (the same object the transformer consumers
    take), and `policy(idx)` translates each `LayerPolicy` to the paper's
    2-bit quantization level via its keep size.  The default plan is the
    paper's off-line regression — aggressive (level 0 = keep 2) early,
    gentle (level 3 = keep 6) deeper, uncompressed past `n_layers`.
    """

    n_layers: int = 10
    bits: int = 8
    plan: plan_lib.CompressionPlan | None = None

    def __post_init__(self):
        if self.plan is None:
            lp = lambda keep: plan_lib.LayerPolicy(keep=keep, bits=self.bits)
            self.plan = plan_lib.CompressionPlan(rules=(
                (self.n_layers, None, plan_lib.LayerPolicy(enabled=False)),
                (0, 2, lp(2)),   # level 0
                (2, 5, lp(3)),   # level 1
                (5, 8, lp(4)),   # level 2
                (8, None, lp(6)),  # level 3
            ))

    @classmethod
    def from_plan(cls, plan: plan_lib.CompressionPlan) -> "CompressionSchedule":
        return cls(plan=plan)

    def policy(self, idx: int) -> compressor.CompressionPolicy | None:
        lp = self.plan.policy(idx)
        if not lp.enabled:
            return None
        return compressor.CompressionPolicy(level=lp.paper_level, bits=lp.bits)


# the accelerator literature calls the conv[+bn][+act][+pool] group a fusion
# layer; expose the schedule under that name too
FusionSchedule = CompressionSchedule


class FusionStats:
    """Per-fusion-layer compression accounting collected during a forward."""

    def __init__(self):
        self.layers: list[dict[str, Any]] = []

    def record(self, idx, name, orig_bits, comp_bits, shape):
        self.layers.append(
            dict(idx=idx, name=name, orig_bits=orig_bits, comp_bits=comp_bits, shape=shape)
        )

    def ratios(self):
        return [l["comp_bits"] / l["orig_bits"] for l in self.layers]

    def overall_ratio(self):
        ob = sum(l["orig_bits"] for l in self.layers)
        cb = sum(l["comp_bits"] for l in self.layers)
        return cb / ob if ob else 1.0


def fusion_boundary(
    x: jax.Array,
    idx: int,
    name: str,
    schedule: CompressionSchedule | None,
    stats: FusionStats | None,
    value_bits: int = 16,
) -> jax.Array:
    """Apply the paper codec at a fusion-layer output.

    NHWC -> (N, C, H, W): the codec's leading-dim handling folds the whole
    (N, C) plane batch into one backend call (fused Pallas kernels on TPU,
    reference einsum elsewhere) — no per-plane Python loop or reshape.

    `schedule` may be a CompressionSchedule or a bare CompressionPlan (the
    transformer consumers' policy object works here unchanged).
    """
    if schedule is None:
        return x
    if isinstance(schedule, plan_lib.CompressionPlan):
        schedule = CompressionSchedule.from_plan(schedule)
    policy = schedule.policy(idx)
    if policy is None:
        if stats is not None:
            bits = x.size * value_bits
            stats.record(idx, name, bits, bits, tuple(x.shape))
        return x
    planes = jnp.transpose(x, (0, 3, 1, 2))  # (N, C, H, W)
    c = codec_lib.paper_compress(planes, policy)
    if stats is not None:
        comp_bits = codec_lib.paper_storage_bits(c)
        stats.record(idx, name, x.size * value_bits, comp_bits, tuple(x.shape))
    y = codec_lib.paper_decompress(c)
    return jnp.transpose(y, (0, 2, 3, 1)).astype(x.dtype)


# --------------------------------------------------------------------------
# VGG-16-BN
# --------------------------------------------------------------------------

VGG16_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M"]


def vgg16_bn_init(key, num_classes=21, cin=3):
    params = []
    c = cin
    for v in VGG16_CFG:
        if v == "M":
            continue
        key, k1, k2 = jax.random.split(key, 3)
        params.append({"conv": conv_init(k1, 3, 3, c, v), "bn": bn_init(k2, v)})
        c = v
    key, kfc = jax.random.split(key)
    params.append({"fc": {"w": jax.random.normal(kfc, (c, num_classes)) * 0.01}})
    return params


def vgg16_bn_apply(params, x, schedule=None, stats=None):
    """Fusion layer = conv+bn+relu (+pool if the next cfg entry is "M") —
    the paper compresses after the full conv/act/pool group."""
    i = 0
    fidx = 0
    for ci, v in enumerate(VGG16_CFG):
        if v == "M":
            continue  # pooling handled by the preceding fusion layer
        p = params[i]
        x = relu(bn(p["bn"], conv(p["conv"], x)))
        i += 1
        if ci + 1 < len(VGG16_CFG) and VGG16_CFG[ci + 1] == "M":
            x = maxpool(x)
        x = fusion_boundary(x, fidx, f"vgg_f{fidx}", schedule, stats)
        fidx += 1
    x = avgpool_global(x)
    return x @ params[-1]["fc"]["w"]


# --------------------------------------------------------------------------
# ResNet-50
# --------------------------------------------------------------------------

RESNET50_STAGES = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]


def _bottleneck_init(key, cin, mid, cout, downsample):
    ks = jax.random.split(key, 8)
    p = {
        "c1": conv_init(ks[0], 1, 1, cin, mid),
        "b1": bn_init(ks[1], mid),
        "c2": conv_init(ks[2], 3, 3, mid, mid),
        "b2": bn_init(ks[3], mid),
        "c3": conv_init(ks[4], 1, 1, mid, cout),
        "b3": bn_init(ks[5], cout),
    }
    if downsample:
        p["cd"] = conv_init(ks[6], 1, 1, cin, cout)
        p["bd"] = bn_init(ks[7], cout)
    return p


def resnet50_init(key, num_classes=21, cin=3):
    key, k0, k1 = jax.random.split(key, 3)
    params = {"stem": {"conv": conv_init(k0, 7, 7, cin, 64), "bn": bn_init(k1, 64)}, "blocks": []}
    c = 64
    for (n, mid, cout, stride) in RESNET50_STAGES:
        for b in range(n):
            key, kb = jax.random.split(key)
            params["blocks"].append(
                {
                    "p": _bottleneck_init(kb, c, mid, cout, downsample=(c != cout or (b == 0 and stride > 1))),
                    "stride": stride if b == 0 else 1,
                }
            )
            c = cout
    key, kfc = jax.random.split(key)
    params["fc"] = {"w": jax.random.normal(kfc, (c, num_classes)) * 0.01}
    return params


def resnet50_apply(params, x, schedule=None, stats=None):
    p = params["stem"]
    x = relu(bn(p["bn"], conv(p["conv"], x, stride=2)))
    x = maxpool(x, 3, 2)
    fidx = 0
    x = fusion_boundary(x, fidx, "stem", schedule, stats)
    fidx += 1
    for blk in params["blocks"]:
        bp, stride = blk["p"], blk["stride"]
        y = relu(bn(bp["b1"], conv(bp["c1"], x)))
        y = relu(bn(bp["b2"], conv(bp["c2"], y, stride=stride)))
        y = bn(bp["b3"], conv(bp["c3"], y))
        if "cd" in bp:
            x = bn(bp["bd"], conv(bp["cd"], x, stride=stride))
        x = relu(x + y)
        x = fusion_boundary(x, fidx, f"block{fidx}", schedule, stats)
        fidx += 1
    return avgpool_global(x) @ params["fc"]["w"]


# --------------------------------------------------------------------------
# MobileNet-v1 / v2
# --------------------------------------------------------------------------

MBV1_CFG = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + [(512, 1)] * 5 + [(1024, 2), (1024, 1)]


def mobilenet_v1_init(key, num_classes=21, cin=3, width=1.0):
    key, k0, k1 = jax.random.split(key, 3)
    c = int(32 * width)
    params = {"stem": {"conv": conv_init(k0, 3, 3, cin, c), "bn": bn_init(k1, c)}, "blocks": []}
    for (cout, stride) in MBV1_CFG:
        cout = int(cout * width)
        ks = jax.random.split(key, 6)
        key = ks[0]
        params["blocks"].append(
            {
                "dw": conv_init(ks[1], 3, 3, c, c, depthwise=True),
                "bnd": bn_init(ks[2], c),
                "pw": conv_init(ks[3], 1, 1, c, cout),
                "bnp": bn_init(ks[4], cout),
                "stride": stride,
            }
        )
        c = cout
    key, kfc = jax.random.split(key)
    params["fc"] = {"w": jax.random.normal(kfc, (c, num_classes)) * 0.01}
    return params


def mobilenet_v1_apply(params, x, schedule=None, stats=None):
    p = params["stem"]
    x = relu(bn(p["bn"], conv(p["conv"], x, stride=2)))
    fidx = 0
    x = fusion_boundary(x, fidx, "stem", schedule, stats)
    fidx += 1
    for blk in params["blocks"]:
        x = relu(bn(blk["bnd"], conv(blk["dw"], x, stride=blk["stride"], depthwise=True)))
        x = relu(bn(blk["bnp"], conv(blk["pw"], x)))
        x = fusion_boundary(x, fidx, f"dsep{fidx}", schedule, stats)
        fidx += 1
    return avgpool_global(x) @ params["fc"]["w"]


MBV2_CFG = [
    # (expansion t, cout, n, stride)
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2_init(key, num_classes=21, cin=3):
    key, k0, k1 = jax.random.split(key, 3)
    c = 32
    params = {"stem": {"conv": conv_init(k0, 3, 3, cin, c), "bn": bn_init(k1, c)}, "blocks": []}
    for (t, cout, n, stride) in MBV2_CFG:
        for b in range(n):
            mid = c * t
            ks = jax.random.split(key, 8)
            key = ks[0]
            blk = {
                "exp": conv_init(ks[1], 1, 1, c, mid) if t != 1 else None,
                "bne": bn_init(ks[2], mid) if t != 1 else None,
                "dw": conv_init(ks[3], 3, 3, mid, mid, depthwise=True),
                "bnd": bn_init(ks[4], mid),
                "pw": conv_init(ks[5], 1, 1, mid, cout),
                "bnp": bn_init(ks[6], cout),
                "stride": stride if b == 0 else 1,
                "res": (c == cout),
            }
            params["blocks"].append(blk)
            c = cout
    key, k2, k3, kfc = jax.random.split(key, 4)
    params["head"] = {"conv": conv_init(k2, 1, 1, c, 1280), "bn": bn_init(k3, 1280)}
    params["fc"] = {"w": jax.random.normal(kfc, (1280, num_classes)) * 0.01}
    return params


def mobilenet_v2_apply(params, x, schedule=None, stats=None):
    p = params["stem"]
    x = relu6(bn(p["bn"], conv(p["conv"], x, stride=2)))
    fidx = 0
    x = fusion_boundary(x, fidx, "stem", schedule, stats)
    fidx += 1
    for blk in params["blocks"]:
        y = x
        if blk["exp"] is not None:
            y = relu6(bn(blk["bne"], conv(blk["exp"], y)))
        y = relu6(bn(blk["bnd"], conv(blk["dw"], y, stride=blk["stride"], depthwise=True)))
        y = bn(blk["bnp"], conv(blk["pw"], y))  # linear bottleneck: DENSE output
        x = x + y if (blk["res"] and blk["stride"] == 1) else y
        x = fusion_boundary(x, fidx, f"ir{fidx}", schedule, stats)
        fidx += 1
    x = relu6(bn(params["head"]["bn"], conv(params["head"]["conv"], x)))
    return avgpool_global(x) @ params["fc"]["w"]


# --------------------------------------------------------------------------
# YOLO-v3 backbone (Darknet-53, leaky-ReLU => dense feature maps)
# --------------------------------------------------------------------------

DARKNET_STAGES = [(1, 64), (2, 128), (8, 256), (8, 512), (4, 1024)]


def darknet53_init(key, cin=3):
    key, k0, k1 = jax.random.split(key, 3)
    params = {"stem": {"conv": conv_init(k0, 3, 3, cin, 32), "bn": bn_init(k1, 32)}, "stages": []}
    c = 32
    for (n, cout) in DARKNET_STAGES:
        ks = jax.random.split(key, 3)
        key = ks[0]
        stage = {"down": {"conv": conv_init(ks[1], 3, 3, c, cout), "bn": bn_init(ks[2], cout)}, "blocks": []}
        c = cout
        for _ in range(n):
            ks = jax.random.split(key, 5)
            key = ks[0]
            stage["blocks"].append(
                {
                    "c1": conv_init(ks[1], 1, 1, c, c // 2),
                    "b1": bn_init(ks[2], c // 2),
                    "c2": conv_init(ks[3], 3, 3, c // 2, c),
                    "b2": bn_init(ks[4], c),
                }
            )
        params["stages"].append(stage)
    return params


def darknet53_apply(params, x, schedule=None, stats=None):
    p = params["stem"]
    x = leaky_relu(bn(p["bn"], conv(p["conv"], x)))
    fidx = 0
    x = fusion_boundary(x, fidx, "stem", schedule, stats)
    fidx += 1
    for stage in params["stages"]:
        d = stage["down"]
        x = leaky_relu(bn(d["bn"], conv(d["conv"], x, stride=2)))
        x = fusion_boundary(x, fidx, f"down{fidx}", schedule, stats)
        fidx += 1
        for blk in stage["blocks"]:
            y = leaky_relu(bn(blk["b1"], conv(blk["c1"], x)))
            y = leaky_relu(bn(blk["b2"], conv(blk["c2"], y)))
            x = x + y
            x = fusion_boundary(x, fidx, f"res{fidx}", schedule, stats)
            fidx += 1
    return x


# --------------------------------------------------------------------------
# Tiny CNN for the trained accuracy-loss experiment
# --------------------------------------------------------------------------

def tiny_cnn_init(key, num_classes=4, cin=1, width=16):
    ks = jax.random.split(key, 8)
    return {
        "c1": conv_init(ks[0], 3, 3, cin, width),
        "b1": bn_init(ks[1], width),
        "c2": conv_init(ks[2], 3, 3, width, width * 2),
        "b2": bn_init(ks[3], width * 2),
        "c3": conv_init(ks[4], 3, 3, width * 2, width * 4),
        "b3": bn_init(ks[5], width * 4),
        "fc": {"w": jax.random.normal(ks[6], (width * 4, num_classes)) * 0.01,
               "b": jnp.zeros((num_classes,))},
    }


def tiny_cnn_apply(params, x, schedule=None, stats=None, train=False):
    def _bn(p, v):
        if train:  # batch statistics during training
            mean = jnp.mean(v, axis=(0, 1, 2))
            var = jnp.var(v, axis=(0, 1, 2))
            inv = p["gamma"] / jnp.sqrt(var + 1e-5)
            return v * inv + (p["beta"] - mean * inv)
        return bn(p, v)

    x = relu(_bn(params["b1"], conv(params["c1"], x)))
    x = maxpool(x)
    x = fusion_boundary(x, 0, "c1", schedule, stats)
    x = relu(_bn(params["b2"], conv(params["c2"], x)))
    x = maxpool(x)
    x = fusion_boundary(x, 1, "c2", schedule, stats)
    x = relu(_bn(params["b3"], conv(params["c3"], x)))
    x = fusion_boundary(x, 2, "c3", schedule, stats)
    return avgpool_global(x) @ params["fc"]["w"] + params["fc"]["b"]


MODELS = {
    "vgg16_bn": (vgg16_bn_init, vgg16_bn_apply),
    "resnet50": (resnet50_init, resnet50_apply),
    "mobilenet_v1": (mobilenet_v1_init, mobilenet_v1_apply),
    "mobilenet_v2": (mobilenet_v2_init, mobilenet_v2_apply),
    "yolov3_backbone": (darknet53_init, darknet53_apply),
    "tiny_cnn": (tiny_cnn_init, tiny_cnn_apply),
}
