"""Transformer building blocks: norms, RoPE, attention (GQA / MLA), MLPs, MoE.

Conventions:
  * activations (B, S, D) bf16 by default; reductions/norms/softmax in f32.
  * weights are plain jnp arrays in nested dicts; layer-stacked weights carry
    a leading L dimension and are consumed by lax.scan (compact HLO — one
    traced body for 96-layer models, essential for 512-device dry-run compiles).
  * attention is chunked (online-softmax over KV blocks) so no (S, S) score
    tensor ever materializes — memory O(S * block) instead of O(S^2).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import attn_hint, logical as shard_hint

Params = dict[str, Any]

DEFAULT_QUERY_BLOCK = 512
DEFAULT_KV_BLOCK = 1024


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, d_in, d_out, bias=False, std=None, dtype=jnp.bfloat16):
    std = std if std is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


@jax.custom_vjp
def _matmul_bf16_wgrad(w, x):
    return jnp.einsum("...d,df->...f", x, w, preferred_element_type=jnp.float32)


def _mm_fwd(w, x):
    return _matmul_bf16_wgrad(w, x), (w, x)


def _mm_bwd(res, g):
    w, x = res
    gx = jnp.einsum("...f,df->...d", g, w,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    # weight grad in bf16: the MXU accumulates f32 internally; emitting bf16
    # halves the per-layer cross-DP gradient reduce that fires RIGHT HERE
    # (inside the backward scan) — casts applied any later are downstream of
    # the collective (§Perf, deepseek train multi-pod, two refuted attempts).
    gw = jnp.einsum("...d,...f->df", x, g,
                    preferred_element_type=jnp.bfloat16)
    return gw, gx


_matmul_bf16_wgrad.defvjp(_mm_fwd, _mm_bwd)


def dense(p, x):
    y = _matmul_bf16_wgrad(p["w"], x)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(d, dtype=jnp.bfloat16):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.bfloat16):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked causal attention (online softmax over KV blocks, GQA-aware).
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def chunked_attention(
    q: jax.Array,           # (B, Sq, H, hd)
    k: jax.Array,           # (B, Sk, Hkv, hd)
    v: jax.Array,           # (B, Sk, Hkv, hd_v)
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,   # absolute position of q[0] (decode)
    kv_block: int = DEFAULT_KV_BLOCK,
    scale: float | None = None,
) -> jax.Array:
    """Flash-style attention: scan over KV blocks with running (max, sum, acc).

    Never materializes (Sq, Sk) — the working set is (Sq, kv_block), so 32k
    prefill and 512k contexts compile within per-device HBM.
    """
    b, sq, h, hd = q.shape
    _, sk, hkv, _ = k.shape
    hd_v = v.shape[-1]
    n_rep = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kv_block = min(kv_block, sk)
    nblocks = (sk + kv_block - 1) // kv_block
    pad = nblocks * kv_block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    k = _repeat_kv(k, n_rep).reshape(b, nblocks, kv_block, h, hd)
    v = _repeat_kv(v, n_rep).reshape(b, nblocks, kv_block, h, hd_v)

    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + q_offset  # (Sq,)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, blk = inp
        kv_pos = blk * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else jnp.ones((sq, kv_block), bool)
        valid = kv_pos < sk  # mask the tail padding
        mask = mask & valid[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf): exp(-inf - -inf) -> use 0
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0),
            jnp.arange(nblocks),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B, Sq, H, hd_v)


def decode_attention(
    q: jax.Array,           # (B, 1, H, hd)
    k: jax.Array,           # (B, S, Hkv, hd)   S-sharded cache friendly
    v: jax.Array,           # (B, S, Hkv, hd_v)
    pos: jax.Array,         # (B,) per-slot positions (scalar broadcasts):
    *,                      # row b attends to <= pos[b]
    scale: float | None = None,
) -> jax.Array:
    """Single-shot decode attention (no KV-chunk scan).

    For one query token the score tensor is only (B, H, S) — there is
    nothing to tile. Crucially this keeps the SEQUENCE dim contraction-
    friendly under GSPMD: with the cache S-sharded (the layout when kv-heads
    don't divide the model axis), softmax stats and the PV contraction
    reduce over S with tiny (B, H) / (B, H, hd) all-reduces instead of the
    involuntary cache replication a chunked dynamic-slice scan causes.
    """
    b, sq, h, hd = q.shape
    _, s, hkv, hd_v = v.shape
    n_rep = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    # cache layout under a serve mesh: slots on data, kv heads on model when
    # divisible (else S on model) — same rule as sharding.kv_cache_spec, so
    # the scatter-updated cache flows in without a reshard
    k = attn_hint(k)
    v = attn_hint(v)
    # bf16-native contractions with f32 accumulation (MXU semantics): casting
    # the cache to f32 would make XLA materialize a full f32 copy of the
    # stacked cache per layer (measured 87 GB/step of pure convert churn on
    # yi-6b decode_32k).
    qg = (q[:, 0] * scale).astype(k.dtype).reshape(b, hkv, n_rep, hd)
    sc = jnp.einsum("bgrd,bsgd->bgrs", qg, k,
                    preferred_element_type=jnp.float32)  # (B, Hkv, rep, S)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    valid = jnp.arange(s)[None] <= posv[:, None]         # (B, S) per-row horizon
    sc = jnp.where(valid[:, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    ks = _split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype=dtype),
    }


def gqa_project_kv(p, x, positions, cfg):
    b, s, _ = x.shape
    hd = cfg.head_dim
    k = dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_attention(p, x, positions, cfg, *, k=None, v=None, q_offset=0, kv_block=None):
    """Self-attention; pass (k, v) explicitly for decode against a cache.

    TP layout: heads on `model` where divisible (Megatron); the einsums in
    chunked_attention then stay fully local per head-shard and the only
    collective is wo's row-parallel reduce.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    q = attn_hint(q)
    q = apply_rope(q, positions, cfg.rope_theta)
    if k is None:
        k, v = gqa_project_kv(p, x, positions, cfg)
    out = chunked_attention(
        q, k, v, causal=True, q_offset=q_offset,
        kv_block=kv_block or DEFAULT_KV_BLOCK,
    )
    out = attn_hint(out)
    return dense(p["wo"], out.reshape(b, s, cfg.n_heads * hd))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype=jnp.bfloat16):
    ks = _split(key, 8)
    d = cfg.d_model
    h = cfg.n_heads
    r = cfg.kv_lora_rank
    qr = cfg.q_lora_rank
    nope = cfg.qk_nope_head_dim
    rope = cfg.qk_rope_head_dim
    vh = cfg.v_head_dim
    p = {
        # KV path: down-project to the latent, decoupled rope key from x
        "wkv_a": dense_init(ks[0], d, r + rope, dtype=dtype),
        "kv_a_norm": rmsnorm_init(r, dtype),
        "wkv_b": dense_init(ks[1], r, h * (nope + vh), dtype=dtype),
        "wo": dense_init(ks[2], h * vh, d, dtype=dtype),
    }
    if qr:
        p["wq_a"] = dense_init(ks[3], d, qr, dtype=dtype)
        p["q_a_norm"] = rmsnorm_init(qr, dtype)
        p["wq_b"] = dense_init(ks[4], qr, h * (nope + rope), dtype=dtype)
    else:
        p["wq"] = dense_init(ks[5], d, h * (nope + rope), dtype=dtype)
    return p


def mla_latent(p, x, positions, cfg):
    """Compute the cached quantities: latent c_kv (B,S,r) and rope key (B,S,rope)."""
    kv = dense(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_a_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, positions, cfg, *, c_kv=None, k_rope=None, q_offset=0, kv_block=None):
    """MLA: queries against the up-projected latent KV.

    The cache stores only (c_kv, k_rope) — (r + rope) per token instead of
    2*H*hd: the *learned* compression the paper's fixed DCT basis is compared
    against in DESIGN.md §4.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if c_kv is None:
        c_kv, k_rope = mla_latent(p, x, positions, cfg)
    if "wq_a" in p:
        q = dense(p["wq_b"], rmsnorm(p["q_a_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, h, nope + rope)
    q = attn_hint(q)
    q_nope, q_rope = jnp.split(q, [nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = dense(p["wkv_b"], c_kv).reshape(b, -1, h, nope + vh)
    kv = shard_hint(kv, "batch", None, "model", None)
    k_nope, v = jnp.split(kv, [nope], axis=-1)
    sk = k_nope.shape[1]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, sk, h, rope))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(
        q_full, k_full, v, causal=True, q_offset=q_offset,
        kv_block=kv_block or DEFAULT_KV_BLOCK,
        scale=1.0 / np.sqrt(nope + rope),
    )
    return dense(p["wo"], out.reshape(b, s, h * vh))


def mla_decode_attention(p, x, positions, cfg, c_kv, k_rope, pos):
    """MLA decode with weight absorption: attention runs in the LATENT space.

    Instead of up-projecting the whole cached latent to per-head K/V every
    step (S x H x (nope+vh) work and memory), fold wkv_b into the query and
    output sides:

        q_lat = q_nope @ Wk_head           (b, 1, h, r)
        score = q_lat . c_kv + q_rope . k_rope
        o_lat = softmax(score) . c_kv      (b, 1, h, r)
        out   = o_lat @ Wv_head

    The S-contractions touch only the rank-r latent (r=512 vs h*(nope+vh) =
    32768 for deepseek-v2) — 64x less decode bandwidth, and S-sharding-
    friendly under GSPMD.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    if "wq_a" in p:
        q = dense(p["wq_b"], rmsnorm(p["q_a_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, h, nope + rope)
    q = attn_hint(q)
    # latent cache layout (sharding.latent_cache_spec): slots on data, S on
    # model — the rank-r contractions below then reduce over the model axis
    # with tiny (B, H) partials instead of gathering the latent store
    c_kv = shard_hint(c_kv, "batch", "model", None)
    k_rope = shard_hint(k_rope, "batch", "model", None)
    q_nope, q_rope = jnp.split(q, [nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    wkv_b = p["wkv_b"]["w"].reshape(r, h, nope + vh)
    wk, wv = wkv_b[..., :nope], wkv_b[..., nope:]

    # bf16-native latent contractions, f32 accumulation (see decode_attention)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(wk.dtype), wk,
                       preferred_element_type=jnp.float32)
    sc = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(c_kv.dtype), c_kv,
                    preferred_element_type=jnp.float32)
    sc = sc + jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(k_rope.dtype), k_rope,
                         preferred_element_type=jnp.float32)
    sc = sc / np.sqrt(nope + rope)
    skv = c_kv.shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    valid = jnp.arange(skv)[None] <= posv[:, None]       # (B, S) per-row horizon
    sc = jnp.where(valid[:, None, None], sc, -jnp.inf)
    prob = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", prob.astype(c_kv.dtype), c_kv,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(wv.dtype), wv,
                     preferred_element_type=jnp.float32)
    return dense(p["wo"], out.reshape(b, s, h * vh).astype(x.dtype))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff=None, dtype=jnp.bfloat16):
    d_ff = d_ff or cfg.d_ff
    ks = _split(key, 3)
    if cfg.mlp_type == "gated_silu":
        return {
            "wg": dense_init(ks[0], cfg.d_model, d_ff, dtype=dtype),
            "wu": dense_init(ks[1], cfg.d_model, d_ff, dtype=dtype),
            "wd": dense_init(ks[2], d_ff, cfg.d_model, dtype=dtype),
        }
    # squared_relu (nemotron) and gelu (whisper/qwen-style) are 2-matrix MLPs
    return {
        "wu": dense_init(ks[0], cfg.d_model, d_ff, dtype=dtype),
        "wd": dense_init(ks[1], d_ff, cfg.d_model, dtype=dtype),
    }


def mlp(p, x, cfg):
    if cfg.mlp_type == "gated_silu":
        g = shard_hint(dense(p["wg"], x), "batch", None, "model")
        u = shard_hint(dense(p["wu"], x), "batch", None, "model")
        return dense(p["wd"], jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u)
    h = shard_hint(dense(p["wu"], x), "batch", None, "model")
    if cfg.mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wd"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based chunked dispatch, EP-shardable)
# ---------------------------------------------------------------------------

def _expert_ffn(xe, wg, wu, wd):
    """Expert matmuls. Batch-dim dots stay bf16->bf16: XLA:CPU has no
    BF16 x BF16 = F32 batch-dot runtime thunk (jit or eager), and on TPU a
    bf16-out dot still accumulates f32 inside the MXU. Elementwise math is
    upcast explicitly. Returns yo (b, e, cap, d) f32."""
    h = jnp.einsum("becd,edf->becf", xe, wg)
    u = jnp.einsum("becd,edf->becf", xe, wu)
    h = (jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xe.dtype)
    h = shard_hint(h, "batch", "model", None, None)
    return jnp.einsum("becf,efd->becd", h, wd).astype(jnp.float32)

def moe_init(key, cfg, dtype=jnp.bfloat16):
    e = cfg.n_experts
    dm, df = cfg.d_model, cfg.moe_d_ff
    ks = _split(key, 5)
    std = 1.0 / np.sqrt(dm)
    p = {
        "router": dense_init(ks[0], dm, e, dtype=jnp.float32),
        # expert weights stacked on a leading E axis => EP shards axis 0
        "wg": (jax.random.normal(ks[1], (e, dm, df), jnp.float32) * std).astype(dtype),
        "wu": (jax.random.normal(ks[2], (e, dm, df), jnp.float32) * std).astype(dtype),
        "wd": (jax.random.normal(ks[3], (e, df, dm), jnp.float32) / np.sqrt(df)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts, dtype=dtype)
    return p


def moe_ffn(p, x, cfg, *, group_size: int | None = None,
            capacity_factor: float | None = None, dropless: bool | None = None):
    """Top-k routed experts with per-group capacity dispatch.

    Tokens are processed in groups of `group_size` (lax.scan) so the dispatch
    one-hot is (G, E, C) with C = G*topk/E*cf — bounded VMEM/HBM no matter the
    sequence length.  The einsums keep a clean E axis for expert parallelism:
    GSPMD turns the (tokens->experts) resharding into an all-to-all on the
    'model' mesh axis.

    `dropless=True` sets capacity = group size (no token ever dropped) — used
    by the decode path, where groups are tiny and losing a token corrupts the
    stream.
    """
    group_size = cfg.moe_group_size if group_size is None else group_size
    capacity_factor = cfg.moe_capacity_factor if capacity_factor is None else capacity_factor
    dropless = cfg.moe_dropless if dropless is None else dropless
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(group_size, s)
    assert s % g == 0, (s, g)
    cap = g if dropless else max(8, int(np.ceil(g * k / e * capacity_factor)))
    cap = min(cap, g)
    # groups are SEQUENCE slices per batch row: the scan axis (s//g) is
    # unsharded while the batch dim stays on DP, so every device advances the
    # group loop in lockstep on its own rows (no cross-device group traffic),
    # and expert weights are re-read only s/g times per layer.
    ngroups = s // g
    groups = jnp.moveaxis(x.reshape(b, ngroups, g, d), 1, 0)    # (nG, b, g, d)

    router_w = p["router"]["w"].astype(jnp.float32)

    def per_group(xg):                                          # (b, g, d)
        logits = jnp.einsum("bgd,de->bge", xg.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, k)                    # (b, g, k)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)     # renorm over top-k
        onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)     # (b, g, k, e)
        # per-row position of each (token, slot) within its expert queue
        pos = jnp.cumsum(onehot.reshape(b, g * k, e), axis=1).reshape(b, g, k, e) - 1.0
        keep = (pos < cap) * onehot                             # drop overflow
        # mask carriers in bf16: exact for 0/1 values, halves the HBM cost of
        # the (b, g, k, e, cap) dispatch tensor
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.bfloat16)
        keep16 = keep.astype(jnp.bfloat16)
        disp = jnp.einsum("bgke,bgkec->bgec", keep16, pos_oh)   # (b, g, e, cap)
        comb = jnp.einsum("bgk,bgke,bgkec->bgec",
                          topv.astype(jnp.bfloat16), keep16, pos_oh)
        # EP: the dispatch einsum reshards tokens -> expert-major (all-to-all
        # on `model`); expert matmuls then run local to each expert shard.
        xe = jnp.einsum("bgec,bgd->becd", disp, xg.astype(jnp.bfloat16))
        xe = shard_hint(xe, "batch", "model", None, None)
        yo = _expert_ffn(xe, p["wg"], p["wu"], p["wd"])
        yg = jnp.einsum("bgec,becd->bgd", comb.astype(jnp.float32), yo)
        return yg.astype(x.dtype)

    if ngroups == 1:
        y = per_group(groups[0])[None]
    elif ngroups <= 8:
        # unrolled: the backward then sums the per-group expert-weight grad
        # contributions BEFORE the cross-DP reduction — one all-reduce per
        # layer instead of one per group (§Perf, deepseek train multi-pod)
        y = jnp.stack([per_group(groups[i]) for i in range(ngroups)])
    else:
        y = jax.lax.map(per_group, groups)
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x, cfg)
    return y
