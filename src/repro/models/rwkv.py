"""RWKV-6 "Finch" (attention-free, data-dependent decay) [arXiv:2404.05892].

Per layer: time-mix (the wkv linear-attention recurrence with per-channel
data-dependent decay w_t produced by a low-rank MLP of the shifted input) and
channel-mix (squared-ReLU gated FFN with token shift).

Recurrence per head (state S in R^{N x N}, N = head_dim):
    out_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t

Train path is chunked: intra-chunk via decay-factored matmuls in log space
(r~_t = r_t * exp(lw_t), k~_s = k_s * exp(-lw_s); lw clamped >= LOG_W_MIN per
step so f32 exponents stay bounded — decays this small are off-distribution),
inter-chunk via a state scan.  Decode path is the exact recurrence.

Simplification vs. the released model (DESIGN.md §4): the data-dependent
token-shift (ddlerp) LoRA is replaced by static lerp mixes; the data-dependent
*decay* — the defining RWKV-6 feature — is kept in its LoRA form.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = dict[str, Any]
LOG_W_MIN = -4.0     # per-step clamp on log decay (numerics, see module doc)
DECAY_LORA = 64


def _shift(x):
    """Token shift: x_{t-1} with zero at t=0. x: (B, S, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def rwkv_layer_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    n = cfg.head_dim
    ks = jax.random.split(key, 12)
    return {
        "ln1": L.layernorm_init(d, dtype),
        "ln2": L.layernorm_init(d, dtype),
        # time-mix
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": L.dense_init(ks[0], d, d, dtype=dtype),
        "wk": L.dense_init(ks[1], d, d, dtype=dtype),
        "wv": L.dense_init(ks[2], d, d, dtype=dtype),
        "wg": L.dense_init(ks[3], d, d, dtype=dtype),
        "wo": L.dense_init(ks[4], d, d, dtype=dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "wA": (jax.random.normal(ks[5], (d, DECAY_LORA), jnp.float32) * 0.01).astype(dtype),
        "wB": (jax.random.normal(ks[6], (DECAY_LORA, d), jnp.float32) * 0.01).astype(dtype),
        "u": jnp.zeros((h, n), jnp.float32),  # per-channel bonus
        "ln_x": L.layernorm_init(d, dtype),   # per-head group norm (folded)
        # channel-mix
        "cmix_k": jnp.full((d,), 0.5, dtype),
        "cmix_r": jnp.full((d,), 0.5, dtype),
        "ck": L.dense_init(ks[7], d, cfg.d_ff, dtype=dtype),
        "cv": L.dense_init(ks[8], cfg.d_ff, d, dtype=dtype),
        "cr": L.dense_init(ks[9], d, d, dtype=dtype),
    }


def _decay(p, xw):
    """log w_t (negative), per channel: (B, S, D) -> f32."""
    lora = jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32), p["wA"].astype(jnp.float32))
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(lora), p["wB"].astype(jnp.float32))
    logw = -jnp.exp(p["w0"][None, None, :] + lora)
    return jnp.maximum(logw, LOG_W_MIN)


def _time_mix_projections(p, x, cfg):
    xs = _shift(x)

    def mix(m):
        return x * p[m].astype(x.dtype) + xs * (1.0 - p[m].astype(x.dtype))

    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    r = L.dense(p["wr"], mix("mix_r")).reshape(b, s, h, n)
    k = L.dense(p["wk"], mix("mix_k")).reshape(b, s, h, n)
    v = L.dense(p["wv"], mix("mix_v")).reshape(b, s, h, n)
    g = L.dense(p["wg"], mix("mix_g"))
    logw = _decay(p, mix("mix_w")).reshape(b, s, h, n)
    return r, k, v, g, logw


def _wkv_chunked(r, k, v, logw, u, chunk: int):
    """Chunked wkv: r,k,v (B,S,H,N); logw (B,S,H,N) negative; u (H,N)."""
    b, s, h, n = r.shape
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q
    rf = r.astype(jnp.float32).reshape(b, nc, q, h, n)
    kf = k.astype(jnp.float32).reshape(b, nc, q, h, n)
    vf = v.astype(jnp.float32).reshape(b, nc, q, h, n)
    lw = logw.reshape(b, nc, q, h, n)
    # within-chunk cumulative decay EXCLUSIVE of t: prod_{u<t} w_u
    lw_cum = jnp.cumsum(lw, axis=2) - lw            # (B,nc,Q,H,N)
    lw_total = lw_cum[:, :, -1] + lw[:, :, -1]      # full chunk decay (B,nc,H,N)

    r_dec = rf * jnp.exp(lw_cum)                    # r~_t = r_t prod_{u<t} w
    k_dec = kf * jnp.exp(-(lw_cum + lw))            # k~_s = k_s / prod_{u<=s} w
    # A[t,s] = sum_n r~[t]k~[s] valid for s < t  (strictly lower triangular)
    att = jnp.einsum("bcqhn,bckhn->bchqk", r_dec, k_dec)
    smask = jnp.tril(jnp.ones((q, q), bool), k=-1)
    att = jnp.where(smask[None, None, None], att, 0.0)
    y_intra = jnp.einsum("bchqk,bckhn->bcqhn", att, vf)
    # diagonal bonus term: r_t (diag(u) k_t^T v_t) = (r_t . u*k_t) v_t
    diag = jnp.einsum("bcqhn,hn,bcqhn->bcqh", rf, u, kf)
    y_intra = y_intra + diag[..., None] * vf

    # chunk-local state contribution: sum_s prod_{s<u<=Q} w * k_s^T v_s
    dec_to_end = jnp.exp(lw_total[:, :, None] - (lw_cum + lw))  # (B,nc,Q,H,N)
    s_local = jnp.einsum("bcqhn,bcqhm->bchnm", kf * dec_to_end, vf)

    def scan_fn(s_prev, inp):
        dec, s_loc = inp                            # (B,H,N), (B,H,N,M)
        s_new = s_prev * jnp.exp(dec)[..., None] + s_loc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    _, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(lw_total, 1, 0), jnp.moveaxis(s_local, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)           # (B,nc,H,N,M)
    y_inter = jnp.einsum("bcqhn,bchnm->bcqhm", r_dec, s_prevs)
    return (y_intra + y_inter).reshape(b, s, h, n)


def rwkv_time_mix(p, x, cfg):
    b, s, d = x.shape
    r, k, v, g, logw = _time_mix_projections(p, x, cfg)
    y = _wkv_chunked(r, k, v, logw, p["u"], cfg.ssm_chunk)
    y = y.reshape(b, s, d)
    y = L.layernorm(p["ln_x"], y.astype(x.dtype))
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return L.dense(p["wo"], y)


def rwkv_channel_mix(p, x):
    xs = _shift(x)
    xk = x * p["cmix_k"].astype(x.dtype) + xs * (1.0 - p["cmix_k"].astype(x.dtype))
    xr = x * p["cmix_r"].astype(x.dtype) + xs * (1.0 - p["cmix_r"].astype(x.dtype))
    k = L.dense(p["ck"], xk)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = L.dense(p["cv"], k)
    return jax.nn.sigmoid(L.dense(p["cr"], xr).astype(jnp.float32)).astype(x.dtype) * v


def rwkv_layer(p, x, cfg):
    x = x + rwkv_time_mix(p, L.layernorm(p["ln1"], x), cfg)
    x = x + rwkv_channel_mix(p, L.layernorm(p["ln2"], x))
    return x


def init_rwkv(key, cfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    keys = jax.random.split(ks[0], cfg.n_layers)
    return {
        "embed": (jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "ln_in": L.layernorm_init(cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: rwkv_layer_init(k, cfg, dtype))(keys),
        "final_norm": L.layernorm_init(cfg.d_model, dtype),
        "lm_head": (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size), jnp.float32) / np.sqrt(cfg.d_model)).astype(dtype),
    }


def rwkv_forward(params, tokens, cfg, *, remat: str = "full", **_) -> jax.Array:
    x = params["embed"][tokens].astype(params["embed"].dtype)
    x = L.layernorm(params["ln_in"], x)

    def body(p, h):
        return rwkv_layer(p, h, cfg)

    if remat != "none":
        body = jax.checkpoint(body)

    def step(h, p):
        return body(p, h), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    h = L.layernorm(params["final_norm"], x)
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Decode (exact recurrence; state = (S, x_prev_tm, x_prev_cm) per layer)
# ---------------------------------------------------------------------------

def init_rwkv_cache(cfg, batch: int, dtype=jnp.bfloat16) -> Params:
    h, n, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "S": jnp.zeros((cfg.n_layers, batch, h, n, n), jnp.float32),
        "x_tm": jnp.zeros((cfg.n_layers, batch, d), dtype),
        "x_cm": jnp.zeros((cfg.n_layers, batch, d), dtype),
    }


def rwkv_decode_step(params, token, cache, pos, cfg):
    x = params["embed"][token].astype(params["embed"].dtype)  # (B, D)
    x = L.layernorm(params["ln_in"], x[:, None, :])[:, 0]
    b, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim

    def layer_step(carry, inp):
        xx = carry
        p, S, x_tm, x_cm = inp
        xn = L.layernorm(p["ln1"], xx[:, None, :])[:, 0]

        def mix(m, prev):
            return xn * p[m].astype(xn.dtype) + prev * (1.0 - p[m].astype(xn.dtype))

        r = L.dense(p["wr"], mix("mix_r", x_tm)).reshape(b, h, n).astype(jnp.float32)
        k = L.dense(p["wk"], mix("mix_k", x_tm)).reshape(b, h, n).astype(jnp.float32)
        v = L.dense(p["wv"], mix("mix_v", x_tm)).reshape(b, h, n).astype(jnp.float32)
        g = L.dense(p["wg"], mix("mix_g", x_tm))
        xw = mix("mix_w", x_tm)
        lora = jnp.tanh(xw.astype(jnp.float32) @ p["wA"].astype(jnp.float32)) @ p["wB"].astype(jnp.float32)
        logw = jnp.maximum(-jnp.exp(p["w0"][None] + lora), LOG_W_MIN).reshape(b, h, n)
        kv = jnp.einsum("bhn,bhm->bhnm", k, v)
        out = jnp.einsum("bhn,bhnm->bhm", r, S + p["u"][None, :, :, None] * kv)
        S_new = jnp.exp(logw)[..., None] * S + kv
        y = out.reshape(b, d).astype(xx.dtype)
        y = L.layernorm(p["ln_x"], y[:, None, :])[:, 0]
        y = y * jax.nn.silu(g.astype(jnp.float32)).astype(xx.dtype)
        xx = xx + L.dense(p["wo"], y[:, None, :])[:, 0]
        new_x_tm = xn

        xcn = L.layernorm(p["ln2"], xx[:, None, :])[:, 0]
        xk = xcn * p["cmix_k"].astype(xcn.dtype) + x_cm * (1.0 - p["cmix_k"].astype(xcn.dtype))
        xr = xcn * p["cmix_r"].astype(xcn.dtype) + x_cm * (1.0 - p["cmix_r"].astype(xcn.dtype))
        kk = L.dense(p["ck"], xk[:, None, :])
        kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(xcn.dtype)
        vv = L.dense(p["cv"], kk)[:, 0]
        rr = jax.nn.sigmoid(L.dense(p["cr"], xr[:, None, :]).astype(jnp.float32))[:, 0]
        xx = xx + rr.astype(xcn.dtype) * vv
        return xx, (S_new, new_x_tm, xcn)

    x, (S_new, xtm_new, xcm_new) = jax.lax.scan(
        layer_step, x, (params["layers"], cache["S"], cache["x_tm"], cache["x_cm"])
    )
    hfin = L.layernorm(params["final_norm"], x[:, None, :])[:, 0]
    logits = jnp.einsum("bd,dv->bv", hfin, params["lm_head"], preferred_element_type=jnp.float32)
    return logits, {"S": S_new, "x_tm": xtm_new, "x_cm": xcm_new}
