"""Mamba2 (chunked SSD) blocks and the Zamba2 hybrid LM.

Mamba2 block: in_proj -> short depthwise conv over (x, B, C) -> SSD selective
state space (chunked block-parallel form: intra-chunk quadratic + inter-chunk
state scan) -> gated RMSNorm -> out_proj.  The chunked form is the
TPU-friendly algorithm: per chunk of Q tokens the work is dense einsums, and
only the (H, P, N) state crosses chunk boundaries via lax.scan.

Zamba2: a stack of Mamba2 blocks with ONE shared attention+MLP block applied
every `attn_every` blocks (weights reused at every application — faithful to
the paper's parameter sharing; we omit the per-invocation LoRA deltas and the
concat-with-embedding input, noted in DESIGN.md).  Forward is two nested
scans: outer over groups, inner over the group's mamba blocks.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = dict[str, Any]
CONV_K = 4  # mamba2 depthwise conv kernel width


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_state


def mamba2_init(key, cfg, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    d_inner, h, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 6)
    return {
        "norm": L.rmsnorm_init(d, dtype),
        # in_proj -> [z (d_inner), xBC (conv_dim), dt (h)]
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner + 2 * n + h, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "out_norm": L.rmsnorm_init(d_inner, dtype),
        "out_proj": L.dense_init(ks[2], d_inner, d, dtype=dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq: xbc (B, S, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, L, N) single group.  Returns y (B, L, H, P).
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    xf = x.astype(jnp.float32)
    la = dt * A[None, None, :]                      # log decay per step (<0)
    lc = la.reshape(b, nc, q, h)
    lcs = jnp.cumsum(lc, axis=2)                    # (B, nc, Q, H) within-chunk
    xc = xf.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = Bm.astype(jnp.float32).reshape(b, nc, q, n)
    cc = Cm.astype(jnp.float32).reshape(b, nc, q, n)

    # ---- intra-chunk (quadratic in Q) ----
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)      # (B, nc, Q, Q)
    li = lcs[:, :, :, None, :] - lcs[:, :, None, :, :]  # (B, nc, Q, K, H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # exp() only on masked-in entries: for t < s the exponent is POSITIVE and
    # can overflow f32 (inf), which the where() discards in the forward but
    # poisons the backward with inf * 0 = NaN. Clamp first.
    li_safe = jnp.where(mask[None, None, :, :, None], li, 0.0)
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li_safe), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp", cb, decay, dtc, xc)

    # ---- chunk-local end states ----
    dec_end = jnp.exp(lcs[:, :, -1:, :] - lcs)      # decay from s to chunk end
    s_local = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn", dec_end, dtc, bc, xc)
    chunk_decay = jnp.exp(lcs[:, :, -1, :])         # (B, nc, H)

    # ---- inter-chunk state scan ----
    def scan_fn(s_prev, inp):
        dec, s_loc = inp                            # (B, H), (B, H, P, N)
        s_new = s_prev * dec[..., None, None] + s_loc
        return s_new, s_prev

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_local, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)           # (B, nc, H, P, N) state before chunk

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, jnp.exp(lcs), s_prevs)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y.astype(x.dtype)


def mamba2_block(p, x, cfg):
    """x: (B, S, D) -> (B, S, D)."""
    d_inner, h, n = _dims(cfg)
    hdim = cfg.ssm_head_dim
    res = x
    xn = L.rmsnorm(p["norm"], x)
    zxbcdt = L.dense(p["in_proj"], xn)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(*xs.shape[:2], h, hdim)
    y = _ssd_chunked(xh, dt, A, bm, cm, cfg.ssm_chunk)
    y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(*xs.shape[:2], d_inner)
    y = L.rmsnorm(p["out_norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return res + L.dense(p["out_proj"], y)


def mamba2_decode_step(p, x, ssm_state, conv_state, cfg):
    """Single-token recurrent step.

    x: (B, 1, D); ssm_state (B, H, P, N); conv_state (B, K-1, conv_dim).
    """
    d_inner, h, n = _dims(cfg)
    hdim = cfg.ssm_head_dim
    res = x
    xn = L.rmsnorm(p["norm"], x)
    zxbcdt = L.dense(p["in_proj"], xn)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    # conv over the rolling window
    win = jnp.concatenate([conv_state, xbc], axis=1)        # (B, K, conv)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))[:, None, :].astype(x.dtype)
    new_conv_state = win[:, 1:, :]
    xs, bm, cm = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])  # (B, H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                              # (B, H)
    xh = xs[:, 0].reshape(-1, h, hdim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, bm[:, 0].astype(jnp.float32), xh)
    new_state = ssm_state * a[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), new_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(p["out_norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return res + L.dense(p["out_proj"], y), new_state, new_conv_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid LM
# ---------------------------------------------------------------------------

def init_zamba(key, cfg, dtype=jnp.bfloat16) -> Params:
    from repro.models import transformer as T

    assert cfg.n_layers % cfg.attn_every == 0
    groups = cfg.n_layers // cfg.attn_every
    ks = jax.random.split(key, 5)
    keys = jax.random.split(ks[0], cfg.n_layers).reshape(groups, cfg.attn_every, -1)
    mamba = jax.vmap(jax.vmap(lambda k: mamba2_init(k, cfg, dtype)))(keys)
    return {
        "embed": (jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "mamba": mamba,  # (groups, attn_every, ...)
        "shared_attn": T.dense_layer_init(ks[2], cfg, dtype),  # ONE shared block
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_size), jnp.float32) / np.sqrt(cfg.d_model)).astype(dtype),
    }


def zamba_forward(params, tokens, cfg, *, remat: str = "full", **_) -> jax.Array:
    from repro.models import transformer as T

    x = params["embed"][tokens].astype(params["embed"].dtype)
    shared = params["shared_attn"]

    def mamba_body(p, h):
        return mamba2_block(p, h, cfg)

    if remat != "none":
        mamba_body = jax.checkpoint(mamba_body)

    def attn_body(h):
        positions = jnp.arange(h.shape[1])[None, :]
        return T.dense_layer(shared, h, positions, cfg)

    if remat != "none":
        attn_body = jax.checkpoint(attn_body)

    def group_step(h, group_params):
        def inner(hh, p):
            return mamba_body(p, hh), None

        h, _ = jax.lax.scan(inner, h, group_params)
        h = attn_body(h)
        return h, None

    x, _ = jax.lax.scan(group_step, x, params["mamba"])
    h = L.rmsnorm(params["final_norm"], x)
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32)


def init_zamba_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
    d_inner, h, n = _dims(cfg)
    groups = cfg.n_layers // cfg.attn_every
    conv_dim = d_inner + 2 * n
    hd = cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((groups, cfg.attn_every, batch, h, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((groups, cfg.attn_every, batch, CONV_K - 1, conv_dim), dtype),
        "k": jnp.zeros((groups, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((groups, batch, max_seq, cfg.n_kv_heads, hd), dtype),
    }


def zamba_decode_step(params, token, cache, pos, cfg, *, kv_block: int = 1024, unroll: bool = False):
    from repro.models import transformer as T

    x = params["embed"][token][:, None, :].astype(params["embed"].dtype)
    shared = params["shared_attn"]
    positions = jnp.full((1, 1), pos, jnp.int32)

    def group_step(carry, inp):
        h = carry
        gp, ssm_g, conv_g, k_g, v_g = inp

        def inner(hh, blk):
            p, s, c = blk
            out, s2, c2 = mamba2_decode_step(p, hh, s, c, cfg)
            return out, (s2, c2)

        h, (ssm2, conv2) = jax.lax.scan(inner, h, (gp, ssm_g, conv_g))
        # shared attention block against this group's KV cache
        hn = L.rmsnorm(shared["ln1"], h)
        k_new, v_new = L.gqa_project_kv(shared["attn"], hn, positions, cfg)
        k2 = jax.lax.dynamic_update_slice(k_g, k_new.astype(k_g.dtype), (0, pos, 0, 0))
        v2 = jax.lax.dynamic_update_slice(v_g, v_new.astype(v_g.dtype), (0, pos, 0, 0))
        hd = cfg.resolved_head_dim
        q = L.dense(shared["attn"]["wq"], hn).reshape(-1, 1, cfg.n_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        out_h = L.decode_attention(q, k2, v2, pos)  # single-shot decode attn
        h = h + L.dense(shared["attn"]["wo"], out_h.reshape(-1, 1, cfg.n_heads * hd))
        h = h + L.mlp(shared["mlp"], L.rmsnorm(shared["ln2"], h), cfg)
        return h, (ssm2, conv2, k2, v2)

    ngroups = cache["k"].shape[0]
    x, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
        group_step, x,
        (params["mamba"], cache["ssm"], cache["conv"], cache["k"], cache["v"]),
        unroll=ngroups if unroll else 1,
    )
    h = L.rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"], preferred_element_type=jnp.float32)[:, 0]
    return logits, {"ssm": ssm_new, "conv": conv_new, "k": k_new, "v": v_new}
