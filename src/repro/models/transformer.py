"""Decoder-only LM and encoder-decoder transformer (scan-over-layers).

Families covered: dense (yi, qwen2, nemotron, command-r+), moe (deepseek-v2,
moonshot), vlm (llava backbone + vision-stub prefix), audio (whisper enc-dec
+ audio-stub frame embeddings).  zamba2/rwkv live in ssm.py / rwkv.py.

Remat policies (train):
  "none"       — save everything XLA wants
  "full"       — jax.checkpoint per layer (save residual stream only)
  "compressed" — ActCompress (core/activation.py): residuals saved in
                 DCT-truncated int8 — the paper's interlayer compression
                 applied to the saved-for-backward activations.  The kept
                 corner is PER LAYER: `plan=` takes a
                 repro.codec.plan.CompressionPlan and the layer scan splits
                 into one scan per contiguous equal-policy segment (the
                 legacy scalar `compress_keep` is a uniform-plan shim).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.codec import plan as plan_lib
from repro.core import kv_cache as kvc
from repro.core.activation import compressed_checkpoint
from repro.models import layers as L
from repro.parallel.sharding import attn_hint, logical as shard_hint

Params = dict[str, Any]


def _stacked_init(key, n: int, init_fn):
    """vmap an init over a leading layer axis for lax.scan consumption."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _attn_block(p, x, positions, cfg, **kw):
    if cfg.attn_type == "mla":
        return L.mla_attention(p, x, positions, cfg, **kw)
    return L.gqa_attention(p, x, positions, cfg, **kw)


def _attn_init(key, cfg, dtype):
    if cfg.attn_type == "mla":
        return L.mla_init(key, cfg, dtype)
    return L.gqa_init(key, cfg, dtype)


def _norm(cfg):
    return L.layernorm if cfg.norm == "layernorm" else L.rmsnorm


def _norm_init(cfg, d, dtype):
    return L.layernorm_init(d, dtype) if cfg.norm == "layernorm" else L.rmsnorm_init(d, dtype)


def dense_layer_init(key, cfg, dtype=jnp.bfloat16, d_ff=None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": _norm_init(cfg, cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": _norm_init(cfg, cfg.d_model, dtype),
        "mlp": L.mlp_init(k2, cfg, d_ff=d_ff, dtype=dtype),
    }


def moe_layer_init(key, cfg, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _norm_init(cfg, cfg.d_model, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": _norm_init(cfg, cfg.d_model, dtype),
        "moe": L.moe_init(k2, cfg, dtype),
    }


def dense_layer(p, x, positions, cfg):
    norm = _norm(cfg)
    x = x + _attn_block(p["attn"], norm(p["ln1"], x), positions, cfg)
    x = x + L.mlp(p["mlp"], norm(p["ln2"], x), cfg)
    return x


def moe_layer(p, x, positions, cfg):
    norm = _norm(cfg)
    x = x + _attn_block(p["attn"], norm(p["ln1"], x), positions, cfg)
    x = x + L.moe_ffn(p["moe"], norm(p["ln2"], x), cfg)
    return x


def _wrap_remat(body, remat: str, policy: plan_lib.LayerPolicy | None = None):
    # both remat modes route through the custom_vjp wrapper so the per-layer
    # param cotangents are cast to bf16 BEFORE XLA's in-loop DP reduction
    # (halves gradient wire; accumulation stays f32 in the train step)
    if remat == "full":
        return compressed_checkpoint(body, keep=None, grad_dtype=jnp.bfloat16)
    if remat == "compressed":
        policy = policy if policy is not None else plan_lib.LayerPolicy()
        return compressed_checkpoint(body,
                                     keep=policy.keep if policy.enabled else None,
                                     grad_dtype=jnp.bfloat16,
                                     backend=policy.backend)
    return body


# ---------------------------------------------------------------------------
# Decoder-only LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 6)
    params: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "final_norm": _norm_init(cfg, cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        nk = cfg.first_k_dense
        if nk:
            params["dense_layers"] = _stacked_init(
                ks[1], nk, lambda k: dense_layer_init(k, cfg, dtype)
            )
        params["moe_layers"] = _stacked_init(
            ks[2], cfg.n_layers - nk, lambda k: moe_layer_init(k, cfg, dtype)
        )
    else:
        params["layers"] = _stacked_init(
            ks[1], cfg.n_layers, lambda k: dense_layer_init(k, cfg, dtype)
        )
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_size), jnp.float32)
            / np.sqrt(cfg.d_model)
        ).astype(dtype)
    return params


def embed_tokens(params, tokens, cfg, prefix_embeds=None):
    x = params["embed"][tokens].astype(params["embed"].dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return shard_hint(x, "batch", None, None)


def unembed(params, x, cfg):
    h = _norm(cfg)(params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=jnp.float32)
    return shard_hint(logits, "batch", None, "model")


def forward(
    params: Params,
    tokens: jax.Array,                  # (B, S) int32
    cfg,
    *,
    prefix_embeds: jax.Array | None = None,  # (B, P, D) modality stub
    remat: str = "full",
    plan=None,                               # ActCompress CompressionPlan
    compress_keep: int = 4,                  # legacy shim => uniform plan
    codec_backend: str | None = None,        # legacy shim => plan backend
) -> jax.Array:
    """Training/prefill forward -> logits (B, S_total, V)."""
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    plan = plan_lib.as_plan(plan, keep=compress_keep, backend=codec_backend) \
        if remat == "compressed" else None

    def scan_layers(stacked, x, body, layer0):
        # positions derived from h inside the body: the remat wrappers
        # (custom_vjp in particular) must not close over tracers.
        def layer_body(p, h):
            h = shard_hint(h, "batch", None, None)  # residual stream layout
            positions = jnp.arange(h.shape[1])[None, :]
            return body(p, h, positions, cfg)

        def run(x, stk, wrapped):
            def step(h, p):
                return wrapped(p, h), None

            x, _ = jax.lax.scan(step, x, stk)
            return x

        if plan is None:
            return run(x, stacked, _wrap_remat(layer_body, remat))
        # one scan per contiguous equal-policy segment: the per-layer keep
        # is static (it sizes the saved residual), so it cannot ride inside
        # a single scan over all layers
        n = jax.tree.leaves(stacked)[0].shape[0]
        for start, stop, pol in plan.segments(layer0 + n, start=layer0):
            sub = jax.tree.map(lambda p: p[start - layer0:stop - layer0], stacked)
            x = run(x, sub, _wrap_remat(layer_body, remat, pol))
        return x

    if cfg.family == "moe":
        nk = cfg.first_k_dense if "dense_layers" in params else 0
        if nk:
            x = scan_layers(params["dense_layers"], x, dense_layer, 0)
        x = scan_layers(params["moe_layers"], x, moe_layer, nk)
    else:
        x = scan_layers(params["layers"], x, dense_layer, 0)
    return unembed(params, x, cfg)


# ---------------------------------------------------------------------------
# KV cache + prefill + decode
# ---------------------------------------------------------------------------

def prefill(
    params: Params,
    tokens: jax.Array,       # (B, S) prompt (right-padded; pad_mask optional)
    cfg,
    max_seq: int,
    *,
    prefix_embeds: jax.Array | None = None,
    cache_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    """Full-prompt forward that also fills a KV cache of size max_seq.

    Returns (logits (B, S_total, V), cache with entries [0, S_total) written).
    """
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    b, s_total, _ = x.shape
    norm = _norm(cfg)
    positions = jnp.arange(s_total)[None, :]
    pad = max_seq - s_total
    assert pad >= 0, (max_seq, s_total)

    def layer_body(h, p):
        hn = norm(p["ln1"], h)
        if cfg.attn_type == "mla":
            c_kv, k_rope = L.mla_latent(p["attn"], hn, positions, cfg)
            attn_out = L.mla_attention(
                p["attn"], hn, positions, cfg, c_kv=c_kv, k_rope=k_rope
            )
            entry = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))).astype(cache_dtype),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))).astype(cache_dtype),
            }
        else:
            k, v = L.gqa_project_kv(p["attn"], hn, positions, cfg)
            attn_out = L.gqa_attention(p["attn"], hn, positions, cfg, k=k, v=v)
            entry = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache_dtype),
            }
        h = h + attn_out
        if "moe" in p:
            h = h + L.moe_ffn(p["moe"], norm(p["ln2"], h), cfg)
        else:
            h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
        return h, entry

    def run_stack(x, stacked):
        return jax.lax.scan(layer_body, x, stacked)

    if cfg.family == "moe":
        caches = []
        nk = cfg.first_k_dense
        if nk:
            x, cache_d = run_stack(x, params["dense_layers"])
            caches.append(cache_d)
        x, cache_m = run_stack(x, params["moe_layers"])
        caches.append(cache_m)
        cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *caches) \
            if len(caches) > 1 else caches[0]
    else:
        x, cache = run_stack(x, params["layers"])
    return unembed(params, x, cfg), cache


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Params:
    """Stacked raw cache (the baseline; compressed cache lives in core/kv_cache)."""
    hd = cfg.resolved_head_dim
    if cfg.attn_type == "mla":
        return {
            "c_kv": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd), dtype),
    }


def scatter_cache_token(buf: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write `new` (B, 1, ...) at per-row position `pos` (B,) on buf's axis 1.

    Out-of-range positions (idle serve slots parked past max_seq) drop
    silently rather than clamp-overwriting live history.
    """
    b = buf.shape[0]
    return buf.at[jnp.arange(b), pos].set(new[:, 0].astype(buf.dtype), mode="drop")


def decode_step(
    params: Params,
    token: jax.Array,        # (B,) int32 — current token
    cache: Params,
    pos: jax.Array,          # (B,) int32 per-slot write positions
    cfg,                     # (scalar broadcasts — legacy lock-step batching)
    *,
    kv_block: int = 1024,
    unroll: bool = False,
) -> tuple[jax.Array, Params]:
    """One-token decode against a raw KV cache. Returns (logits (B, V), cache).

    Each batch row writes its K/V at its own `pos[b]` and attends under its
    own causal horizon, so rows at different depths share one decode step —
    the raw-cache side of continuous batching.

    unroll=True unrolls the layer loop: cache xs/ys indices become STATIC, so
    XLA emits true in-place per-layer updates instead of the masked-select
    full-cache rewrite a dynamic layer index forces (§Perf, decode cells).
    """
    pos = kvc.as_pos_vec(pos, token.shape[0])
    x = params["embed"][token][:, None, :].astype(params["embed"].dtype)  # (B, 1, D)
    positions = pos[:, None]  # (B, 1) per-row rope positions
    norm = _norm(cfg)

    def layer_step(carry, inp):
        h = carry
        p, cache_slice = inp["p"], inp["cache"]
        hn = norm(p["ln1"], h)
        b = hn.shape[0]
        hd = cfg.resolved_head_dim
        if cfg.attn_type == "mla":
            c_kv_new, k_rope_new = L.mla_latent(p["attn"], hn, positions, cfg)
            c_kv = scatter_cache_token(cache_slice["c_kv"], c_kv_new, pos)
            k_rope = scatter_cache_token(cache_slice["k_rope"], k_rope_new, pos)
            # weight-absorbed latent-space attention (no per-step KV up-proj)
            attn_out = L.mla_decode_attention(
                p["attn"], hn, positions, cfg, c_kv, k_rope, pos
            )
            new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            k_new, v_new = L.gqa_project_kv(p["attn"], hn, positions, cfg)
            k = scatter_cache_token(cache_slice["k"], k_new, pos)
            v = scatter_cache_token(cache_slice["v"], v_new, pos)
            q = L.dense(p["attn"]["wq"], hn).reshape(b, 1, cfg.n_heads, hd)
            q = attn_hint(q)  # heads on `model`: matches the cache spec layout
            q = L.apply_rope(q, positions, cfg.rope_theta)
            out_h = L.decode_attention(q, k, v, pos)  # single-shot (no chunk scan)
            out_h = attn_hint(out_h)
            attn_out = L.dense(p["attn"]["wo"], out_h.reshape(b, 1, cfg.n_heads * hd))
            new_cache = {"k": k, "v": v}
        h = h + attn_out
        if "moe" in p:
            h = h + L.moe_ffn(p["moe"], norm(p["ln2"], h), cfg, dropless=True)
        else:
            h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
        return h, new_cache

    # scan over the layer stack(s)
    def run_stack(x, stacked_params, cache_stack):
        def step(h, inp):
            return layer_step(h, inp)

        nl = jax.tree.leaves(cache_stack)[0].shape[0]
        x, new_cache = jax.lax.scan(
            step, x, {"p": stacked_params, "cache": cache_stack},
            unroll=nl if unroll else 1,
        )
        return x, new_cache

    if cfg.family == "moe":
        nk = cfg.first_k_dense
        new_cache_parts = {}
        if nk:
            cache_d = jax.tree.map(lambda c: c[:nk], cache)
            x, nc_d = run_stack(x, params["dense_layers"], cache_d)
            new_cache_parts["dense"] = nc_d
        cache_m = jax.tree.map(lambda c: c[nk:], cache)
        x, nc_m = run_stack(x, params["moe_layers"], cache_m)
        if nk:
            new_cache = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                new_cache_parts["dense"], nc_m,
            )
        else:
            new_cache = nc_m
    else:
        x, new_cache = run_stack(x, params["layers"], cache)

    logits = unembed(params, x, cfg)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def encdec_layer_init_enc(key, cfg, dtype=jnp.bfloat16):
    return dense_layer_init(key, cfg, dtype)


def encdec_layer_init_dec(key, cfg, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    p = dense_layer_init(k1, cfg, dtype)
    p["ln_x"] = _norm_init(cfg, cfg.d_model, dtype)
    p["xattn"] = L.gqa_init(k2, cfg, dtype)
    return p


def init_encdec(key, cfg, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "pos_embed_dec": (jax.random.normal(ks[1], (cfg.max_seq_len or 448, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "enc_layers": _stacked_init(ks[2], cfg.n_encoder_layers, lambda k: encdec_layer_init_enc(k, cfg, dtype)),
        "dec_layers": _stacked_init(ks[3], cfg.n_layers, lambda k: encdec_layer_init_dec(k, cfg, dtype)),
        "enc_norm": _norm_init(cfg, cfg.d_model, dtype),
        "final_norm": _norm_init(cfg, cfg.d_model, dtype),
    }


def encode_audio(params, frames, cfg, *, remat="full"):
    """frames: (B, T, D) precomputed frame embeddings (conv frontend stub)."""
    norm = _norm(cfg)
    x = frames
    positions = jnp.arange(x.shape[1])[None, :]

    def body(p, h):
        hn = norm(p["ln1"], h)
        b, s, _ = hn.shape
        hd = cfg.resolved_head_dim
        q = L.dense(p["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, hd)
        k = L.dense(p["attn"]["wk"], hn).reshape(b, s, cfg.n_kv_heads, hd)
        v = L.dense(p["attn"]["wv"], hn).reshape(b, s, cfg.n_kv_heads, hd)
        # whisper encoder: no rope (learned/sinusoidal pos handled upstream), non-causal
        o = L.chunked_attention(q, k, v, causal=False)
        h = h + L.dense(p["attn"]["wo"], o.reshape(b, s, -1))
        h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
        return h

    wrapped = _wrap_remat(body, remat)

    def step(h, p):
        return wrapped(p, h), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return norm(params["enc_norm"], x)


def decode_text(params, tokens, enc_out, cfg, *, remat="full"):
    """Teacher-forced decoder -> logits (train/prefill path)."""
    norm = _norm(cfg)
    x = params["embed"][tokens].astype(enc_out.dtype)
    s = x.shape[1]
    x = x + params["pos_embed_dec"][:s][None]
    positions = jnp.arange(s)[None, :]
    b = x.shape[0]
    hd = cfg.resolved_head_dim

    def body(p_and_enc, h):
        # enc_out rides as an explicit input: the remat wrapper is a
        # custom_vjp, which cannot differentiate closed-over tracers
        p, enc = p_and_enc
        hn = norm(p["ln1"], h)
        q = L.dense(p["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, hd)
        k = L.dense(p["attn"]["wk"], hn).reshape(b, s, cfg.n_kv_heads, hd)
        v = L.dense(p["attn"]["wv"], hn).reshape(b, s, cfg.n_kv_heads, hd)
        o = L.chunked_attention(q, k, v, causal=True)
        h = h + L.dense(p["attn"]["wo"], o.reshape(b, s, -1))
        # cross attention over encoder output
        hx = norm(p["ln_x"], h)
        qx = L.dense(p["xattn"]["wq"], hx).reshape(b, s, cfg.n_heads, hd)
        kx = L.dense(p["xattn"]["wk"], enc).reshape(b, enc.shape[1], cfg.n_kv_heads, hd)
        vx = L.dense(p["xattn"]["wv"], enc).reshape(b, enc.shape[1], cfg.n_kv_heads, hd)
        ox = L.chunked_attention(qx, kx, vx, causal=False)
        h = h + L.dense(p["xattn"]["wo"], ox.reshape(b, s, -1))
        h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
        return h

    wrapped = _wrap_remat(body, remat)

    def step(h, p):
        return wrapped((p, enc_out), h), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    h = norm(params["final_norm"], x)
    return jnp.einsum("bsd,vd->bsv", h, params["embed"], preferred_element_type=jnp.float32)


def encdec_forward(params, frames, tokens, cfg, *, remat="full", **_):
    enc = encode_audio(params, frames, cfg, remat=remat)
    return decode_text(params, tokens, enc, cfg, remat=remat)
