"""AdamW with global-norm clipping and cosine LR schedule — pure JAX.

Optimizer state (m, v) is f32 and sharded IDENTICALLY to the params, which
with FSDP param sharding (parallel/sharding.py) gives ZeRO-1/3 semantics for
free: each device owns the optimizer state of exactly the param shards it
holds; no state is ever replicated.

Params may be bf16: the update is computed in f32 from (m, v) and cast back
on write. (No separate f32 master copy — the f32 first moment plus f32 grads
inside the update bound the rounding error; measured adequate for the
synthetic-token training runs in examples/.)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_state(params: Any) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(np.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor), grads), norm


def _is_matrix(p) -> bool:
    return p.ndim >= 2  # decay matrices, not norms/biases/scalars


def apply_updates(
    params: Any, grads: Any, state: dict[str, Any], cfg: AdamWConfig
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """One AdamW step. grads f32 (already averaged over DP). Returns
    (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
