"""Mesh construction and axis conventions.

Axes:
  pod   — slow DCN/ICI-bridge axis between pods. Pure data parallelism;
          crossing it is expensive (GradCompress targets exactly this axis).
  data  — intra-pod data parallelism (batch sharding) + ZeRO-1 optimizer
          state sharding. For long_500k decode it doubles as the sequence axis.
  model — tensor/expert parallelism: attention heads, FFN columns, MoE experts,
          vocab.

Production meshes (assignment): 16x16 = 256 chips single pod;
(2, 16, 16) = 512 chips across 2 pods.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Batch axes: everything data-parallel (pod is DP too, just over slow links).
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def use_mesh(mesh: Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    `jax.set_mesh` only exists on newer jax; on the pinned 0.4.x a `Mesh`
    is itself the legacy global-mesh context manager with the same effect
    for jit + NamedSharding use here.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """`jax.shard_map` compat shim (same pattern as `use_mesh`).

    Newer jax exposes shard_map at the top level with `axis_names=` (manual
    axes) and `check_vma=`; the pinned 0.4.x only has
    `jax.experimental.shard_map.shard_map` with the inverse `auto=` (axes
    left to GSPMD) and `check_rep=`. Every caller (GradCompress pod
    exchange, its tests) goes through here so both jax lines compile.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - frozenset(axis_names) \
        if axis_names is not None else frozenset()
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """A mesh over whatever devices exist (tests / single-host examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """'4x1' -> (data=4, model=1). The serve-mesh CLI grammar."""
    m = spec.lower().split("x")
    if len(m) != 2:
        raise ValueError(f"mesh spec must be DATAxMODEL (e.g. 4x1), got {spec!r}")
    data, model = int(m[0]), int(m[1])
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return data, model


def make_serve_mesh(spec: str | None) -> Mesh | None:
    """Host mesh for serving from a 'DATAxMODEL' spec; None/'' => no mesh.

    Uses the first data*model local devices, so a '2x2' engine can run on a
    4-device host next to a '4x1' one in the same process (tests do exactly
    that under --xla_force_host_platform_device_count).
    """
    if not spec:
        return None
    data, model = parse_mesh_spec(spec)
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {spec} needs {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def mesh_desc(mesh: Mesh | None) -> str:
    """'4x1'-style axis-size summary for logs/artifacts; 'none' without one."""
    if mesh is None:
        return "none"
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    """PartitionSpec entry for a global-batch dimension on this mesh."""
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    if not axes:
        return P(None)
    return P(axes)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in BATCH_AXES:
        n *= mesh_axis_size(mesh, a)
    return n


def shard(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
