"""Mesh construction and axis conventions.

Axes:
  pod   — slow DCN/ICI-bridge axis between pods. Pure data parallelism;
          crossing it is expensive (GradCompress targets exactly this axis).
  data  — intra-pod data parallelism (batch sharding) + ZeRO-1 optimizer
          state sharding. For long_500k decode it doubles as the sequence axis.
  model — tensor/expert parallelism: attention heads, FFN columns, MoE experts,
          vocab.

Production meshes (assignment): 16x16 = 256 chips single pod;
(2, 16, 16) = 512 chips across 2 pods.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Batch axes: everything data-parallel (pod is DP too, just over slow links).
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def use_mesh(mesh: Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    `jax.set_mesh` only exists on newer jax; on the pinned 0.4.x a `Mesh`
    is itself the legacy global-mesh context manager with the same effect
    for jit + NamedSharding use here.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """A mesh over whatever devices exist (tests / single-host examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_spec(mesh: Mesh) -> P:
    """PartitionSpec entry for a global-batch dimension on this mesh."""
    axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    if not axes:
        return P(None)
    return P(axes)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in BATCH_AXES:
        n *= mesh_axis_size(mesh, a)
    return n


def shard(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
