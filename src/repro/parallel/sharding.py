"""Parameter / activation / cache sharding rules (GSPMD partition specs).

Scheme (MaxText-style):
  * TP  — attention heads, FFN columns, vocab on the `model` axis.
  * FSDP — the other big weight dim additionally sharded on `data` (and `pod`
    when present), so 340B-class params fit 16 GB HBM chips. XLA inserts the
    per-layer all-gathers; scan-over-layers keeps them inside the loop body.
  * EP  — MoE expert dim on `model` (dispatch becomes an all-to-all).
  * Activations — batch on (pod, data); saved-for-backward residuals are
    additionally sequence-sharded on `model` (sequence parallelism).

Rules dispatch on the parameter's path (nested dict keys) + ndim, so one rule
set covers all 10 architectures. Fallback: replicate.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.parallel.mesh import BATCH_AXES

# Leaf-key parents whose "w" is sharded on its LAST dim (TP columns):
_COL_PARALLEL = {
    "wq", "wk", "wv", "wg", "wu", "wq_a", "wq_b", "wkv_b", "ck", "cr",
    "exp", "pw", "c1",
}
# Parents whose "w" is sharded on its SECOND-TO-LAST dim (TP rows):
_ROW_PARALLEL = {"wo", "wd", "cv"}
# Parents replicated on model (small / awkward dims):
_REPLICATED = {
    "router", "wkv_a", "kv_a_norm", "q_a_norm", "in_proj", "out_proj",
    "conv", "dw", "fc",
}

# FSDP axis: shard the OTHER big dim of every matrix on the data axes too.
# Enabled per-call; the dry-run enables it for every arch (nothing fits
# otherwise at 340B), tests on 1 device disable it implicitly (axes absent).


def _fsdp_axes(mesh_axes: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh_axes)


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if isinstance(p, DictKey):
            keys.append(str(p.key))
        elif hasattr(p, "name"):      # GetAttrKey (KVSegment fields)
            keys.append(str(p.name))
        elif hasattr(p, "idx"):       # SequenceKey (cache.segments index)
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return keys


def _spec_for_leaf(keys: list[str], leaf, mesh_axes: tuple[str, ...], fsdp: bool) -> P:
    ndim = np.ndim(leaf)
    model = "model" if "model" in mesh_axes else None
    fsdp_ax = _fsdp_axes(mesh_axes) if fsdp else ()
    fsdp_ax = fsdp_ax if fsdp_ax else None

    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""

    def spec(*entries):
        """Pad leading None for stacked layer axes."""
        pad = ndim - len(entries)
        return P(*((None,) * pad + tuple(entries)))

    # ---- embeddings / unembeddings ------------------------------------
    if name == "embed":
        # (V, D): vocab on model (keeps logits V-sharded), D on fsdp.
        return spec(model, fsdp_ax)
    if name in ("lm_head",):
        # (D, V)
        return spec(fsdp_ax, model)
    if name in ("pos_embed_dec",):
        return spec(None, None)

    # ---- MoE expert stacks: raw arrays named wg/wu/wd with an E dim ----
    if name in ("wg", "wu", "wd") and ndim >= 3 and parent == "moe" or (
        name in ("wg", "wu", "wd") and ndim >= 3 and "moe" in keys
    ):
        # (..., E, d_in, d_out): experts on model (EP), d_in on fsdp.
        return spec(model, fsdp_ax, None)

    # ---- dense matrices {parent: {"w": ...}} ---------------------------
    if name == "w":
        if parent in _COL_PARALLEL:
            return spec(fsdp_ax, model)
        if parent in _ROW_PARALLEL:
            return spec(model, fsdp_ax)
        if parent in _REPLICATED:
            # still FSDP-shard the biggest dim so huge replicated mats fit
            if ndim >= 2:
                return spec(fsdp_ax, None)
            return spec()
        if ndim >= 2:
            return spec(fsdp_ax, None)
        return spec()
    if name == "b":
        if parent in _COL_PARALLEL:
            return spec(model)
        return spec()

    # ---- rwkv raw mats (wr/wk/wv/wg live as {"w"} too -> handled above)
    if name in ("wA", "wB", "u", "w0"):
        return spec(*([None] * ndim))

    # ---- mamba conv / scalars ------------------------------------------
    if name in ("conv_w", "conv_b", "A_log", "dt_bias", "D"):
        return spec(*([None] * ndim))

    # ---- norms / small vectors ------------------------------------------
    return spec(*([None] * ndim))


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop / shrink spec entries whose axis sizes don't divide the dim.

    Tuple entries degrade to their longest dividing prefix (e.g. batch 1 on
    ("pod","data") -> replicated; batch 64 on ("pod","data")=32 stays). GSPMD
    CAN pad uneven shardings, but padded params corrupt optimizer norms and
    padded activations waste flops — we never want them implicitly.
    """
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        while names:
            prod = 1
            for n in names:
                prod *= mesh.shape[n]
            if shape[i] % prod == 0 and shape[i] >= prod:
                break
            names = names[:-1]
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
    return P(*out)


def param_specs(params: Any, mesh: Mesh, *, fsdp: bool = True):
    """PartitionSpec pytree matching `params` (nested dicts of arrays)."""
    axes = tuple(mesh.axis_names)

    def rule(path, leaf):
        spec = _spec_for_leaf(_path_keys(path), leaf, axes, fsdp)
        return fit_spec(spec, tuple(leaf.shape), mesh)

    return tree_map_with_path(rule, params)


def param_shardings(params: Any, mesh: Mesh, *, fsdp: bool = True):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh, fsdp=fsdp)
    )


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------

def data_batch_spec(mesh_axes: tuple[str, ...], ndim: int,
                    dim0: int | None = None, mesh: Mesh | None = None) -> P:
    """(B, ...) arrays: batch on all DP axes (longest dividing prefix when
    dim0/mesh are given — a batch of 1 replicates)."""
    axes = tuple(a for a in BATCH_AXES if a in mesh_axes)
    lead = axes if axes else None
    spec = P(*((lead,) + (None,) * (ndim - 1)))
    if dim0 is not None and mesh is not None:
        spec = fit_spec(spec, (dim0,) + (1,) * (ndim - 1), mesh)
    return spec


def step_vec_sharding(mesh: Mesh, batch: int):
    """NamedSharding for the serve loop's device-resident (B,) per-slot
    vectors — the fused decode step's token/position state and its sampled
    token output.  Slots ride the data axes exactly like the pool's batch
    dim, so the step's scatter/gather stays shard-local; a batch the data
    axes don't divide replicates (fit_spec)."""
    from jax.sharding import NamedSharding

    spec = data_batch_spec(tuple(mesh.axis_names), 1, dim0=batch, mesh=mesh)
    return NamedSharding(mesh, spec)


def activation_spec(mesh_axes: tuple[str, ...], *, seq_sharded: bool = False) -> P:
    """(B, S, D) activations: batch on DP; optionally S on model (seq-par)."""
    axes = tuple(a for a in BATCH_AXES if a in mesh_axes)
    lead = axes if axes else None
    model = "model" if (seq_sharded and "model" in mesh_axes) else None
    return P(lead, model, None)


def kv_cache_spec(mesh_axes: tuple[str, ...], n_kv_heads: int, model_size: int,
                  *, stacked: bool = True) -> P:
    """(L, B, S, Hkv, hd) cache: B on data axes; heads on model if divisible,
    else the sequence dim (long caches shard fine over S)."""
    axes = tuple(a for a in BATCH_AXES if a in mesh_axes)
    lead = axes if axes else None
    has_model = "model" in mesh_axes
    head_ok = has_model and n_kv_heads % model_size == 0 and n_kv_heads >= model_size
    if head_ok:
        body = (None, "model", None)
    else:
        body = ("model" if has_model else None, None, None)  # shard S
    entries = (lead,) + body
    if stacked:
        return P(*((None,) + entries))
    return P(*entries)


def latent_cache_spec(mesh_axes: tuple[str, ...], *, stacked: bool = True) -> P:
    """MLA (L, B, S, r) latent cache: B on data, S on model."""
    axes = tuple(a for a in BATCH_AXES if a in mesh_axes)
    lead = axes if axes else None
    model = "model" if "model" in mesh_axes else None
    entries = (lead, model, None)
    if stacked:
        return P(*((None,) + entries))
    return P(*entries)


def ambient_mesh():
    """The mesh whose axes sharding hints may name, or None.

    Newer jax exposes it via `jax.sharding.get_abstract_mesh()` (set by
    `jax.set_mesh`); the pinned 0.4.x has neither, but the legacy
    `with mesh:` context installs a global physical mesh readable through
    `pxla.thread_resources`.  Without this fallback every hint in the model
    and cache code silently no-ops on 0.4.x — decode sharding would then
    rest entirely on GSPMD propagation from the jit boundary."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.axis_names:
            return mesh
    except AttributeError:
        pass
    except Exception:
        return None
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def attn_hint(x: jax.Array, *, s_axis: int = 1, h_axis: int = 2) -> jax.Array:
    """(B, S, H, hd) attention-tensor constraint: heads on `model` when
    divisible (Megatron TP), else SEQUENCE on `model` (context parallelism —
    works for any head count, e.g. qwen2's 14 or whisper's 8 heads; K/V get
    all-gathered per block, which is cheap next to score-sized partial-sum
    all-reduces GSPMD otherwise invents)."""
    try:
        mesh = ambient_mesh()
        if mesh is None or "model" not in mesh.axis_names:
            return x
        msize = mesh.shape["model"]
    except Exception:
        return x
    entries = ["batch"] + [None] * (x.ndim - 1)
    if x.shape[h_axis] % msize == 0 and x.shape[h_axis] >= msize:
        entries[h_axis] = "model"
    elif x.shape[s_axis] % msize == 0 and x.shape[s_axis] >= msize:
        entries[s_axis] = "model"
    return logical(x, *entries)


def _plane_block_ndims() -> dict:
    """Block rank per codec-family plane base name (lazy: parallel must not
    import codec at module scope — codec.api pulls jax program-building
    machinery this leaf module stays independent of)."""
    from repro.codec import families

    return families.plane_block_ndims()


def cache_specs(cache_shapes: Any, cfg, mesh: Mesh):
    """PartitionSpec pytree for a decode cache (raw, latent, recurrent, or
    DCT-compressed). Dispatch on leaf key + rank.

    Accepts plain dicts of planes AND the serve engine's `CompressedKVCache`
    (a tuple of `KVSegment`s — registered with key paths, so each segment's
    packed/scale/tail planes dispatch by name exactly like the dict form).
    Batch slots land on the data axes, kv heads on `model` when divisible —
    the mesh-wide analogue of the paper's banked feature-map buffer: every
    bank (device) owns a fixed slice of the slot pool and of the head planes,
    and decode-step traffic for a slot never leaves its bank."""
    axes = tuple(mesh.axis_names)
    dp = tuple(a for a in BATCH_AXES if a in axes) or None
    has_model = "model" in axes
    msize = mesh.shape["model"] if has_model else 1

    def head_axis_ok(n_heads):
        return has_model and n_heads >= msize and n_heads % msize == 0

    def rule(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):                      # (L|G, B, S, Hkv, hd)
            return kv_cache_spec(axes, cfg.n_kv_heads, msize, stacked=True)
        if name in ("c_kv", "k_rope"):              # (L, B, S, r)
            return latent_cache_spec(axes, stacked=True)
        if name in ("tail_k", "tail_v"):            # (L, B, 8, Hkv, hd)
            h = "model" if head_axis_ok(cfg.n_kv_heads) else None
            return P(None, dp, None, h, None)
        base = name[:-2] if name.endswith(("_k", "_v")) else None
        block_nd = _plane_block_ndims().get(base)
        if block_nd is not None:
            # codec-family block plane (families.plane_block_ndims declares
            # the per-block rank n; dct packed n=3, scale n=1, ...):
            #   paged pool : (L, P, Hkv)      + block_shape  -> rank 3 + n
            #   dense      : (L, B, S/8, Hkv) + block_shape  -> rank 4 + n
            h = "model" if head_axis_ok(cfg.n_kv_heads) else None
            if nd == 3 + block_nd:                  # paged pool
                return P(None, dp, h, *([None] * block_nd))
            assert nd == 4 + block_nd, (name, nd, block_nd)
            return P(None, dp, None if h else ("model" if has_model else None),
                     h, *([None] * block_nd))      # dense
        if name == "block_table":                   # (B, S/8) page ids
            return P(dp, None)
        if name == "ssm":                           # (G, A, B, H, P, N)
            nh = leaf.shape[3]
            h = "model" if (has_model and nh % msize == 0 and nh >= msize) else None
            return P(None, None, dp, h, None, None)
        if name == "conv":                          # (G, A, B, K-1, conv_dim)
            return P(None, None, dp, None, None)
        if name == "S":                             # rwkv (L, B, H, N, N)
            nh = leaf.shape[2]
            h = "model" if (has_model and nh % msize == 0 and nh >= msize) else None
            return P(None, dp, h, None, None)
        if name in ("x_tm", "x_cm"):                # (L, B, D)
            return P(None, dp, None)
        return P(*([None] * nd))

    return tree_map_with_path(
        lambda path, leaf: fit_spec(rule(path, leaf), tuple(leaf.shape), mesh),
        cache_shapes,
    )


def cache_shardings(cache_shapes: Any, cfg, mesh: Mesh):
    """NamedSharding pytree matching `cache_shapes` (see cache_specs)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), cache_specs(cache_shapes, cfg, mesh)
    )


def kv_pool_specs(cfg, plan, mesh: Mesh, *, batch: int, max_seq: int,
                  dtype=None, n_pages: int | None = None):
    """Cache specs for the compressed KV slot pool straight from the plan.

    Builds the `CompressedKVCache` shape tree (one `KVSegment` per contiguous
    equal-policy layer run) without allocating, then applies the cache rules:
    int8 DCT blocks, scales and raw tails sharded on the data axes (batch
    slots) with kv heads on `model` — the same placement `param_specs` gives
    the attention weights, so decode never reshards between them.

    With `n_pages` the tree is the PAGED pool instead: pages and block
    tables shard on the data axes (each device/bank owns a slice of the
    page pool), heads on `model`, tails per slot as before.
    """
    from repro.core import kv_cache as kvc  # lazy: core imports stay one-way

    kw = {} if dtype is None else {"dtype": dtype}
    if n_pages is None:
        shapes = jax.eval_shape(
            lambda: kvc.init_compressed_cache(cfg, batch, max_seq, plan=plan,
                                              **kw))
    else:
        shapes = jax.eval_shape(
            lambda: kvc.init_paged_cache(cfg, batch, max_seq, n_pages,
                                         plan=plan, **kw))
    return cache_specs(shapes, cfg, mesh)


def host_transfer_shardings(tree_shapes: Any, mesh: Mesh):
    """Replicated NamedShardings for host-origin tensors entering the mesh.

    The tiered page pool's host backing store lives OUTSIDE the mesh (plain
    numpy on the serve host); when a parked slot's pages stream back, the
    restore jit takes the numpy update tree as input and scatters it into
    the sharded pool. Pinning the update's in_shardings to replicated makes
    that boundary explicit and deterministic — every device receives the
    handful of restored blocks, and the jit's `out_shardings` (the pool's
    own NamedShardings) re-places the result on the pool's banks, so the
    hot decode path never sees a differently-placed cache. Works for any
    pytree: spill/restore update trees, page-id vectors, table rows.
    """
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: rep, tree_shapes)


def per_device_bytes(shapes: Any, specs: Any, mesh: Mesh) -> float:
    """Bytes each device holds of a pytree sharded per `specs` on `mesh`."""
    leaves = jax.tree.leaves(shapes)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    total = 0.0
    for leaf, spec in zip(leaves, spec_leaves):
        factor = 1
        for entry in spec:
            if entry is None:
                continue
            for name in (entry if isinstance(entry, tuple) else (entry,)):
                factor *= mesh.shape[name]
        itemsize = np.dtype(leaf.dtype).itemsize
        total += int(np.prod(leaf.shape)) * itemsize / factor
    return total


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that no-ops when no mesh context is set
    (keeps single-device unit tests independent of distribution)."""
    try:
        mesh = ambient_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def logical(x: jax.Array, *entries) -> jax.Array:
    """Activation sharding constraint with axis filtering + divisibility.

    `entries` name one spec entry per dim: "batch" (-> all DP axes present),
    "model", or None. Axes absent from the active mesh are dropped; a "model"
    entry whose dim is not divisible by the model-axis size is dropped too
    (GSPMD padding on activations is never worth it). No mesh context => noop.

    This is the single hook every model layer uses — the hillclimb loop
    changes WHERE these are placed, not the models themselves.
    """
    try:
        mesh = ambient_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        # inside a partial-manual shard_map (GradCompress pod exchange) the
        # manual axes must not appear in constraints — they're implicit
        try:
            names -= set(mesh.manual_axes)
        except AttributeError:
            pass
    except Exception:
        return x
    shape = x.shape
    out = []
    for i, e in enumerate(entries):
        if e == "batch":
            dp = tuple(a for a in BATCH_AXES if a in names)
            dpn = 1
            for a in dp:
                dpn *= mesh.shape[a]
            out.append(dp if dp and shape[i] % max(dpn, 1) == 0 else None)
        elif e == "model":
            ok = "model" in names and shape[i] % mesh.shape["model"] == 0 \
                and shape[i] >= mesh.shape["model"]
            out.append("model" if ok else None)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(x, P(*out))


def table_slice_hint(table: jax.Array) -> jax.Array:
    """Placement constraint for a decode-ladder block-table slice.

    A bucket slice `table_view(bt, attend_blocks)` must keep the FULL
    table's placement (`cache_specs`'s block_table rule: slots on the data
    axes, table entries replicated) — otherwise the static slice inside the
    decode step would resolve to a fresh GSPMD decision per bucket and the
    per-bucket jits could disagree on where the gather runs.  One rule,
    applied to every sliced view, keeps all ladder buckets layout-identical
    to the unsliced step."""
    return logical(table, "batch", None)
