"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in SECONDS per step, derived from
the post-SPMD per-device module:

  compute    = HLO_FLOPs / PEAK_FLOPS            (197 TF/s bf16, v5e)
  memory     = HLO_bytes / HBM_BW                (819 GB/s)
  collective = wire_bytes / ICI_BW               (~50 GB/s/link)

Sources: `compiled.cost_analysis()` supplies per-device FLOPs and bytes
(the compiled module is the per-device SPMD program). Collective bytes are
NOT in cost_analysis; we parse `compiled.as_text()` and charge each op the
ring-algorithm wire cost per device:

  all-reduce       2 x operand bytes      (reduce-scatter + all-gather ring)
  all-gather       result - operand       (receives everyone else's shard)
  reduce-scatter   operand - result
  all-to-all       operand bytes          (sends all but its own slice)
  collective-permute  operand bytes

The dominant term approximates step time on hardware that overlaps the other
two perfectly; the roofline fraction we report is dominant / sum (how close
a perfect-overlap schedule would run to the single-resource bound).

MODEL_FLOPS accounting: 6*N*D for training (fwd 2ND + bwd 4ND), 2*N*D for
prefill, 2*N_active per generated token for decode — divided by chip count
to compare against the per-device HLO FLOPs; the ratio exposes remat
recompute and padding waste.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link
# Per-grid-step DMA latency a double-buffered pallas pipeline cannot hide
# when each step's tiles are tiny (a paged-attend page is ~100s of bytes):
# issue + descriptor + HBM round-trip tail, ~0.5us. A kernel whose grid has
# many small steps is latency-bound long before it is bandwidth-bound —
# exactly what multi-page (G) tiling amortizes.
PAGE_DMA_LATENCY_S = 0.5e-6

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g. "%ag = bf16[4,128]{1,0} all-gather(bf16[1,128]{1,0} %x), ..."
_OP_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\((?P<operands>.*?)\)(?:,|\s|$)"
)


def _shape_bytes(typestr: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-kind {count, operand_bytes, result_bytes, wire_bytes} from HLO."""
    out = {k: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0,
               "wire_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(k in line for k in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        res = _shape_bytes(m.group("result"))
        ops = _shape_bytes(m.group("operands"))
        if kind == "all-reduce":
            wire = 2.0 * ops
        elif kind == "all-gather":
            wire = max(res - ops, 0)
        elif kind == "reduce-scatter":
            wire = max(ops - res, 0)
        else:  # all-to-all, collective-permute
            wire = float(ops)
        d = out[kind]
        d["count"] += 1
        d["operand_bytes"] += ops
        d["result_bytes"] += res
        d["wire_bytes"] += wire
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per device
    hlo_bytes: float                 # per device
    wire_bytes: float                # per device
    model_flops_global: float        # analytic useful FLOPs (whole step)
    collectives: dict = field(default_factory=dict)
    memory_stats: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """dominant / sum: 1.0 = one resource fully hides the others."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.bound_s / s if s else 0.0

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device); <1 => remat/padding waste."""
        per_dev = self.model_flops_global / max(self.chips, 1)
        return per_dev / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model FLOPs utilization IF the step ran at bound_s."""
        per_dev = self.model_flops_global / max(self.chips, 1)
        return per_dev / (self.bound_s * PEAK_FLOPS) if self.bound_s else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            roofline_fraction=self.roofline_fraction,
            useful_flop_ratio=self.useful_flop_ratio,
            mfu_bound=self.mfu_bound,
        )
        return d


def hbm_bandwidth_row(bytes_per_step: float, compute_flops: float = 0.0,
                      grid_steps: float = 0.0,
                      mxu_efficiency: float = 1.0) -> dict:
    """Achieved vs peak HBM bandwidth for one (memory-streaming) step.

    `bytes_per_step` is what the kernel ACTUALLY streams (for attend_paged:
    only pages mapped in the block table, their scales, the raw tails, and
    the table itself — never unmapped pool capacity). The step-time bound is
    the roofline max of the memory, compute, and grid-latency terms;
    achieved bandwidth is the useful stream over that bound, so
    `hbm_utilization` < 1 exactly when the step leaves the memory system
    idle waiting on compute or on per-tile DMA issue.

    `grid_steps` charges PAGE_DMA_LATENCY_S per pallas grid step — the
    un-hideable tail of a tiny-tile double-buffered pipeline (0 = dense
    streaming kernel, latency folded into bandwidth). `mxu_efficiency`
    derates PEAK_FLOPS for tiles narrower than the 128-lane contraction
    (a one-page tile runs 8/128 of the MXU).
    """
    mem_s = bytes_per_step / HBM_BW
    comp_s = compute_flops / (PEAK_FLOPS * max(mxu_efficiency, 1e-9))
    lat_s = grid_steps * PAGE_DMA_LATENCY_S
    step_s = max(mem_s, comp_s, lat_s)
    achieved = bytes_per_step / step_s if step_s else 0.0
    return {
        "bytes_per_step": float(bytes_per_step),
        "step_bound_s": step_s,
        "memory_s": mem_s,
        "compute_s": comp_s,
        "grid_latency_s": lat_s,
        "grid_steps": float(grid_steps),
        "achieved_bw_gbs": achieved / 1e9,
        "peak_bw_gbs": HBM_BW / 1e9,
        "hbm_utilization": achieved / HBM_BW,
    }


def model_flops(cfg, shape_name: str, n_layers_factor: float = 1.0) -> float:
    """Analytic useful FLOPs per step: 6ND train / 2ND prefill / 2ND' decode."""
    from repro.configs.base import SHAPES

    seq, batch, kind = SHAPES[shape_name]
    counts = cfg.param_counts()
    n_active = counts["active"]
    if cfg.is_encoder_decoder:
        seq = min(seq, cfg.max_seq_len or seq) + cfg.encoder_seq_len
    if kind == "train":
        return 6.0 * n_active * batch * seq
    if kind == "prefill":
        return 2.0 * n_active * batch * seq
    # decode: one token per sequence in the batch + attention re-read cost
    # (attention flops ~ 2 * 2 * S * d_model * n_layers, folded into n_active
    #  only approximately; report pure 2*N_active*B as the conventional bound)
    return 2.0 * n_active * batch


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  compiled, cfg) -> Roofline:
    """Derive the three terms from the compiled per-device module.

    FLOPs/bytes/wire come from the trip-count-aware HLO analyzer
    (roofline/hlo.py) — XLA's own cost_analysis counts while bodies once and
    undercounts scan-over-layers models by ~L x; its raw values are kept in
    the record as `xla_cost_analysis` for cross-reference.
    """
    from repro.roofline import hlo as hlo_lib

    st = hlo_lib.analyze(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    try:
        ms = compiled.memory_analysis()
        mem = {
            "argument_bytes": ms.argument_size_in_bytes,
            "output_bytes": ms.output_size_in_bytes,
            "temp_bytes": ms.temp_size_in_bytes,
            "alias_bytes": ms.alias_size_in_bytes,
        }
    except Exception:
        mem = {}
    mem["xla_cost_analysis"] = {
        "flops_body_once": float(ca.get("flops", 0.0)),
        "bytes_body_once": float(ca.get("bytes accessed", 0.0)),
    }
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=st.flops, hlo_bytes=st.bytes, wire_bytes=st.wire,
        model_flops_global=model_flops(cfg, shape),
        collectives=st.coll, memory_stats=mem,
    )


def format_row(r: Roofline) -> str:
    return (
        f"{r.arch:24s} {r.shape:12s} {r.mesh:10s} "
        f"comp {r.compute_s*1e3:9.2f}ms  mem {r.memory_s*1e3:9.2f}ms  "
        f"coll {r.collective_s*1e3:9.2f}ms  dom={r.dominant:10s} "
        f"frac={r.roofline_fraction:.2f} useful={r.useful_flop_ratio:.2f}"
    )
