"""HLO cost analyzer with while-loop trip-count accounting.

Why not `compiled.cost_analysis()`: XLA's analysis counts a while body ONCE
regardless of trip count (measured: an 8-iteration scan reports 1 matmul of
FLOPs). Every model here scans over layers / microbatches / KV chunks, so
that undercounts FLOPs, bytes AND collective traffic by 10-100x. This module
parses `compiled.as_text()` (the post-SPMD per-device module), builds the
computation call graph, and rolls costs up with multipliers:

  while(...)  body x known_trip_count (backend_config), cond x same
  call(...)   to_apply x 1
  conditional  max over branches
  fusion      FLOPs of inner dots roll up; BYTES charged at the call site
              (operands + result = one kernel's HBM traffic)

Per-op models (TPU kernel view: each top-level op reads operands once from
HBM and writes its result once):

  flops: dot = 2 * numel(result) * prod(contracting dims); conv analogous.
  bytes: "perfect producer fusion" model — elementwise/broadcast/reduce ops
         charge only their RESULT bytes (the producer's write; the consumer's
         read is charged by the consumer when it is a memory op, and assumed
         fused otherwise — this is what a TPU fusion emitter achieves).
         dot/conv/fusion/copy charge operands + result; slicing ops charge
         the touched region: dynamic-slice 2*result, dynamic-update-slice
         2*update, gather 2*result, scatter 2*updates.
  wire:  ring-model collective cost (see roofline/analysis.py).

This is a structural model, not a simulator — but it is exact on FLOPs for
dot-dominated programs and its scan multiplication is what makes the terms
meaningful at all.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(?P<type>\([^)]*\)|\S+)\s+(?P<opcode>[\w\-]+)\("
)
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
_ATTR_COMP = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation)=%([\w\.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}

# ops whose operand reads we assume fused away (producer wrote them; a TPU
# fusion emitter consumes them in-register/VMEM): charge result bytes only.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "is-finite", "not",
    "and", "or", "xor", "compare", "select", "convert", "broadcast",
    "reshape", "transpose", "reverse", "reduce", "clamp", "concatenate",
    "pad", "slice", "map", "reduce-window", "erf", "expm1", "log1p",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "remainder",
    "atan2", "real", "imag", "complex", "rng", "rng-bit-generator",
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(typestr: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(typestr):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(typestr: str) -> int:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _split_operands(argstr: str) -> list[str]:
    """Split 'f32[1,2] %a, (f32[3]) %b' into operand type strings."""
    parts, depth, cur = [], 0, []
    for ch in argstr:
        if ch == "(" or ch == "[" or ch == "{":
            depth += 1
        elif ch == ")" or ch == "]" or ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _args_of(line: str) -> str:
    """Text inside the opcode's parens."""
    i = line.find("(", line.find("= "))
    # find matching close paren
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j]
    return ""


@dataclass
class OpStats:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    coll: dict = field(default_factory=dict)

    def add(self, other: "OpStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.wire += other.wire * mult
        for k, v in other.coll.items():
            d = self.coll.setdefault(k, {"count": 0.0, "wire_bytes": 0.0})
            d["count"] += v["count"] * mult
            d["wire_bytes"] += v["wire_bytes"] * mult


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)      # raw op lines
    types: dict = field(default_factory=dict)    # %name -> result type str
    local: OpStats = field(default_factory=OpStats)
    children: list = field(default_factory=list)  # (name, mult, flops_only)


_TRANSPARENT = {"convert", "bitcast", "copy", "reshape"}


def _fusion_bytes(fused: "Computation", operand_types: list[str],
                  result_type: str) -> float:
    """HBM bytes of one fusion kernel, from what it actually TOUCHES —
    modelled as a TPU fusion, not the CPU-legalized HLO.

    * a parameter consumed only through dynamic-slice/gather reads slice-
      sized bytes; anything else reads the full parameter;
    * when the ROOT (looking through convert/bitcast/copy chains — the CPU
      backend legalizes bf16 by staging through f32, which a TPU compile
      never emits) is a dynamic-update-slice whose destination chain reaches
      a parameter, the kernel is an in-place region update: charge 2x the
      update region and drop that parameter's "read";
    * convert/bitcast staging of parameters feeding only that aliased DUS
      destination is free.
    """
    param_idx: dict[str, int] = {}
    producer_op: dict[str, tuple[str, list[str]]] = {}  # result -> (opcode, operand names)
    root_name = None
    for line in fused.ops:
        rm = _RESULT_RE.match(line)
        m = _OP_RE.match(line)
        if not m or not rm:
            continue
        opcode = m.group("opcode")
        names = _NAME_RE.findall(_args_of(line))
        producer_op[rm.group(1)] = (opcode, names)
        if opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                param_idx[rm.group(1)] = int(pm.group(1))
        if line.lstrip().startswith("ROOT"):
            root_name = rm.group(1)

    def walk(nm: str, limit: int = 8) -> str | None:
        """Follow transparent ops to the underlying producer name."""
        for _ in range(limit):
            op = producer_op.get(nm)
            if op is None:
                return nm
            opcode, names = op
            if opcode in _TRANSPARENT and names:
                nm = names[0]
            else:
                return nm
        return nm

    # detect in-place DUS through transparent chains
    dus_written: float | None = None
    aliased_param: int | None = None
    alias_chain: set[str] = set()
    if root_name:
        base = walk(root_name)
        op = producer_op.get(base)
        if op and op[0] == "dynamic-update-slice":
            opcode, names = op
            if names:
                dest = names[0]
                # update region size: operand 1's type
                upd_base = names[1] if len(names) > 1 else None
                if upd_base and upd_base in fused.types:
                    dus_written = float(_type_bytes(fused.types[upd_base]))
                # walk the destination chain to a param
                cur = dest
                for _ in range(8):
                    alias_chain.add(cur)
                    pop = producer_op.get(cur)
                    if pop is None:
                        break
                    if cur in param_idx:
                        aliased_param = param_idx[cur]
                        break
                    if pop[0] in _TRANSPARENT and pop[1]:
                        cur = pop[1][0]
                    else:
                        break
                if cur in param_idx:
                    aliased_param = param_idx[cur]

    sliced_reads: dict[int, float] = {}
    full_read: set[int] = set()
    for line in fused.ops:
        rm = _RESULT_RE.match(line)
        m = _OP_RE.match(line)
        if not m or not rm:
            continue
        opcode = m.group("opcode")
        rtype = m.group("type")
        if opcode == "parameter":
            continue
        # converts/copies that only stage the aliased destination are free
        if opcode in _TRANSPARENT and rm.group(1) in alias_chain:
            continue
        names = _NAME_RE.findall(_args_of(line))
        for j, nm in enumerate(names):
            if nm not in param_idx:
                continue
            i = param_idx[nm]
            if i == aliased_param:
                continue  # in-place destination, not a read
            if opcode in ("dynamic-slice", "gather") and j == 0:
                sliced_reads[i] = sliced_reads.get(i, 0.0) + _type_bytes(rtype)
            elif opcode == "dynamic-update-slice" and walk(rm.group(1)) and \
                    rm.group(1) in alias_chain and j == 0:
                pass
            else:
                full_read.add(i)

    total = 0.0
    for i, t in enumerate(operand_types):
        tb = _type_bytes(t)
        if i == aliased_param:
            continue
        if i in full_read:
            total += tb
        elif i in sliced_reads:
            total += min(sliced_reads[i], tb)
    if dus_written is not None and aliased_param is not None:
        total += 2.0 * dus_written          # read-modify-write of the region
    else:
        total += _type_bytes(result_type)   # plain kernel write
    return total


_NAME_RE = re.compile(r"%[\w\.\-]+")
_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+[\w\-]+\(")


def _operand_types(line: str, types: dict) -> list[str]:
    """Resolve operand names in the op's parens to their declared types.

    Optimized HLO prints operands as bare %names; types come from the
    computation's symbol table."""
    args = _args_of(line)
    out = []
    for part in _split_operands(args):
        part = part.strip()
        names = _NAME_RE.findall(part)
        if names:
            out.append(types.get(names[0], part))
        else:
            out.append(part)  # inline literal/typed operand
    return out


def _dot_flops(line: str, result_type: str, types: dict) -> float:
    operands = _operand_types(line, types)
    if not operands:
        return 0.0
    lhs = operands[0]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    sm = _SHAPE_RE.search(lhs)
    if not sm:
        return 0.0
    dims = [int(x) for x in sm.group(2).split(",") if x]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * _numel(result_type) * k


def _conv_flops(line: str, result_type: str, types: dict) -> float:
    operands = _operand_types(line, types)
    if len(operands) < 2:
        return 0.0
    rhs = operands[1]
    sm = _SHAPE_RE.search(rhs)
    if not sm:
        return 0.0
    kdims = [int(x) for x in sm.group(2).split(",") if x]
    mg = re.search(r"feature_group_count=(\d+)", line)
    groups = int(mg.group(1)) if mg else 1
    knumel = 1
    for d in kdims:
        knumel *= d
    # macs per output element = kernel numel / output_features (groups fold in)
    rm = _SHAPE_RE.search(result_type)
    rdims = [int(x) for x in rm.group(2).split(",") if x] if rm else [1]
    out_f = rdims[-1] if rdims else 1
    macs = knumel / max(out_f, 1)
    return 2.0 * _numel(result_type) * macs


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hdr = _COMP_HDR.match(line) if not line.startswith(" ") else None
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and ("=" in s) and "(" in s:
            cur.ops.append(s)
    comps["__entry__"] = comps.get(entry_name, Computation("__missing__"))
    return comps


def analyze(text: str) -> OpStats:
    comps = parse_module(text)
    entry = comps.pop("__entry__")

    # pass 1: symbol tables (result name -> type), incl. parameters
    for comp in comps.values():
        for line in comp.ops:
            rm = _RESULT_RE.match(line)
            if rm:
                comp.types[rm.group(1)] = rm.group(2)

    # pass 2: per-computation local stats + child references
    for comp in comps.values():
        for line in comp.ops:
            m = _OP_RE.match(line)
            if not m:
                continue
            rtype, opcode = m.group("type"), m.group("opcode")
            base = opcode.replace("-start", "").replace("-done", "")
            # ---- child computations -------------------------------------
            refs = _ATTR_COMP.findall(line)
            if opcode == "while":
                tm = _TRIP_RE.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                for r in refs:
                    # body and cond both execute `trip` times
                    comp.children.append((r, trip, False))
                continue  # while op itself moves no data (aliased tuple)
            elif opcode == "conditional":
                branches = _BRANCHES.search(line)
                names = []
                if branches:
                    names = re.findall(r"%([\w\.\-]+)", branches.group(1))
                names += refs
                # charge the most expensive branch
                if names:
                    comp.children.append((tuple(set(names)), 1.0, "max"))
            elif opcode == "fusion":
                for r in refs:
                    comp.children.append((r, 1.0, True))  # flops only
            elif opcode in ("call", "custom-call", "map", "reduce", "sort",
                            "reduce-window", "select-and-scatter", "scatter",
                            "all-reduce", "reduce-scatter"):
                for r in refs:
                    comp.children.append((r, 1.0, True))

            # ---- local costs ---------------------------------------------
            st = comp.local
            if opcode == "dot":
                st.flops += _dot_flops(line, rtype, comp.types)
            elif opcode == "convolution":
                st.flops += _conv_flops(line, rtype, comp.types)

            if base in _COLL_KINDS and not opcode.endswith("-done"):
                ops_b = sum(_type_bytes(o) for o in _operand_types(line, comp.types))
                res_b = _type_bytes(rtype)
                if base == "all-reduce":
                    wire = 2.0 * ops_b
                elif base == "all-gather":
                    wire = max(res_b - ops_b, 0.0)
                elif base == "reduce-scatter":
                    wire = max(ops_b - res_b, 0.0)
                else:
                    wire = float(ops_b)
                st.wire += wire
                d = st.coll.setdefault(base, {"count": 0.0, "wire_bytes": 0.0})
                d["count"] += 1
                d["wire_bytes"] += wire

            if opcode in _SKIP_BYTES or opcode.endswith("-done"):
                continue
            res_b = _type_bytes(rtype)
            operands = _operand_types(line, comp.types)
            ops_b = sum(_type_bytes(o) for o in operands)
            if opcode == "fusion" and refs and refs[0] in comps:
                st.bytes += _fusion_bytes(comps[refs[0]], operands, rtype)
            elif opcode == "dynamic-slice":
                st.bytes += 2.0 * res_b
            elif opcode == "dynamic-update-slice":
                upd = _type_bytes(operands[1]) if len(operands) > 1 else res_b
                st.bytes += 2.0 * upd
            elif opcode == "gather":
                st.bytes += 2.0 * res_b
            elif opcode == "scatter":
                upd = _type_bytes(operands[-1]) if operands else res_b
                st.bytes += 2.0 * upd
            elif opcode == "while":
                pass
            elif opcode in _ELEMENTWISE:
                st.bytes += res_b  # producer write; reads assumed fused
            else:
                st.bytes += res_b + ops_b

    # roll up with memoization (call graph is a DAG)
    memo: dict[tuple, OpStats] = {}

    def total(name: str, flops_only: bool) -> OpStats:
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        out = OpStats()
        memo[key] = out  # cycle guard
        if comp is None:
            return out
        if flops_only:
            out.flops += comp.local.flops
            out.wire += comp.local.wire   # collectives still real inside calls
            for k, v in comp.local.coll.items():
                d = out.coll.setdefault(k, {"count": 0.0, "wire_bytes": 0.0})
                d["count"] += v["count"]
                d["wire_bytes"] += v["wire_bytes"]
        else:
            out.add(comp.local)
        for child, mult, mode in comp.children:
            if mode == "max":
                best = None
                for nm in child:
                    cand = total(nm, flops_only)
                    if best is None or cand.flops + cand.bytes > best.flops + best.bytes:
                        best = cand
                if best:
                    out.add(best, mult)
            else:
                child_flops_only = bool(mode) or flops_only
                out.add(total(child, child_flops_only), mult)
        memo[key] = out
        return out

    return total(entry.name, False)


def breakdown(text: str, top: int = 20) -> list[tuple[float, str, str]]:
    """Top single-op byte contributors WITH their loop multipliers applied.

    Returns [(bytes, 'comp_name xMULT', op_line_prefix)]. The profiling view
    the perf loop reads — 'which op line, executed how many times, moves the
    most HBM bytes'.
    """
    comps = parse_module(text)
    entry = comps.pop("__entry__")
    for comp in comps.values():
        for line in comp.ops:
            rm = _RESULT_RE.match(line)
            if rm:
                comp.types[rm.group(1)] = rm.group(2)

    # compute each computation's total execution multiplier from the entry
    mult: dict[str, float] = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult.get(name, 0.0)
        for line in comp.ops:
            om = _OP_RE.match(line)
            if not om:
                continue
            opcode = om.group("opcode")
            if opcode == "fusion":
                continue  # fusion inner ops are priced at the CALL SITE
            refs = _ATTR_COMP.findall(line)
            tm = _TRIP_RE.search(line)
            trip = float(tm.group(1)) if (opcode == "while" and tm) else 1.0
            for r in refs:
                mult[r] = mult.get(r, 0.0) + m * trip
                if r not in seen:
                    seen.add(r)
                    order.append(r)

    rows = []
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.ops:
            om = _OP_RE.match(line)
            if not om:
                continue
            rtype, opcode = om.group("type"), om.group("opcode")
            if opcode in _SKIP_BYTES or opcode.endswith("-done"):
                continue
            res_b = _type_bytes(rtype)
            operands = _operand_types(line, comp.types)
            ops_b = sum(_type_bytes(o) for o in operands)
            refs = _ATTR_COMP.findall(line)
            if opcode == "fusion" and refs and refs[0] in comps:
                b = _fusion_bytes(comps[refs[0]], operands, rtype)
            elif opcode == "dynamic-slice":
                b = 2.0 * res_b
            elif opcode == "dynamic-update-slice":
                b = 2.0 * (_type_bytes(operands[1]) if len(operands) > 1 else res_b)
            elif opcode == "gather":
                b = 2.0 * res_b
            elif opcode == "scatter":
                b = 2.0 * (_type_bytes(operands[-1]) if operands else res_b)
            elif opcode == "while":
                continue
            elif opcode in _ELEMENTWISE:
                b = float(res_b)
            else:
                b = float(res_b + ops_b)
            rows.append((b * m, f"{name} x{m:.0f}", line[:180]))
    rows.sort(reverse=True, key=lambda t: t[0])
    return rows[:top]
