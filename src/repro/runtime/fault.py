"""Fault-tolerance runtime: preemption handling, straggler detection,
heartbeats, elastic restart bookkeeping.

What "node failure" means here: on a real TPU fleet the coordinator restarts
the job on the surviving (or replacement) slice; the framework's job is to
(a) lose at most `save_every` steps of work, (b) notice it is about to be
killed and checkpoint immediately, (c) come back with a possibly different
data-parallel size and replay the data stream exactly, and (d) flag chronic
stragglers so the operator can cordon the host. All four are implemented
below and exercised in tests/test_fault.py — on one host the signals are
simulated, which is the honest limit of this container.
"""
from __future__ import annotations

import json
import os
import signal
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag the train loop polls each step.

    Cloud TPU preemptions deliver SIGTERM ~30 s before the VM dies; a step
    takes far less, so poll-at-step-boundary + immediate checkpoint loses
    nothing. Use as a context manager around the train loop.
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._previous: dict[int, Any] = {}
        self.triggered = threading.Event()

    def __enter__(self):
        for s in self._signals:
            self._previous[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.triggered.set()

    @property
    def should_stop(self) -> bool:
        return self.triggered.is_set()

    def __exit__(self, *exc):
        for s, prev in self._previous.items():
            signal.signal(s, prev)
        return False


@dataclass
class StragglerMonitor:
    """Flags hosts/steps whose duration is an outlier vs the trailing window.

    On a real fleet each host reports step wall-time via the coordinator;
    here `record(host, seconds)` is fed locally. A host is a straggler when
    its trailing-mean exceeds `threshold` x the fleet median.
    """

    window: int = 32
    threshold: float = 1.8
    _times: dict[int, deque] = field(default_factory=dict)

    def record(self, host: int, seconds: float):
        self._times.setdefault(host, deque(maxlen=self.window)).append(seconds)

    def host_mean(self, host: int) -> float:
        t = self._times.get(host)
        return float(np.mean(t)) if t else 0.0

    def fleet_median(self) -> float:
        means = [self.host_mean(h) for h in self._times]
        return float(np.median(means)) if means else 0.0

    def stragglers(self) -> list[int]:
        med = self.fleet_median()
        if med <= 0:
            return []
        return [h for h in self._times if self.host_mean(h) > self.threshold * med]

    def mitigation(self, host: int) -> str:
        """Policy string for the coordinator (logged; acted on upstream)."""
        if host in self.stragglers():
            return "cordon+reassign" if self.host_mean(host) > 3 * self.fleet_median() \
                else "deprioritize-collectives"
        return "none"


class Heartbeat:
    """Liveness file other processes / the coordinator can watch."""

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self):
        def beat():
            while not self._stop.wait(self.interval_s):
                self._touch()
        self._touch()
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()
        return self

    def _touch(self):
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"t": time.time(), "pid": os.getpid()}, f)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1)

    @staticmethod
    def age(path: str) -> float:
        try:
            with open(path) as f:
                return time.time() - json.load(f)["t"]
        except (OSError, ValueError, KeyError):
            return float("inf")


@dataclass(frozen=True)
class ElasticPlan:
    """Deterministic data replay across a dp-size change.

    The synthetic TokenStream is a pure function of (step, shard, num_shards):
    after restart with dp' != dp, shard i of dp' simply generates its own
    batches — no shared state, no duplicated or skipped samples WITHIN a
    step. Checkpoint granularity guarantees step-level exactness; the pair
    (resume_step, dp') fully determines the input stream.
    """

    resume_step: int
    old_dp: int
    new_dp: int

    def shard_for(self, process: int) -> tuple[int, int]:
        return process % self.new_dp, self.new_dp


def train_loop(
    step_fn: Callable,
    state: Any,
    batches: Callable[[int], Any],
    *,
    start_step: int,
    num_steps: int,
    save_every: int,
    save_fn: Callable[[int, Any], Any],
    monitor: StragglerMonitor | None = None,
    host: int = 0,
) -> tuple[Any, int, str]:
    """Run steps with preemption-safe checkpointing.

    Returns (state, last_step_done, exit_reason in {"done", "preempted"}).
    """
    with PreemptionGuard() as guard:
        step = start_step
        while step < num_steps:
            t0 = time.perf_counter()
            state, _ = step_fn(state, batches(step))
            if monitor is not None:
                monitor.record(host, time.perf_counter() - t0)
            step += 1
            if guard.should_stop:
                save_fn(step, state)
                return state, step, "preempted"
            if step % save_every == 0:
                save_fn(step, state)
    if step % save_every:
        save_fn(step, state)
    return state, step, "done"
