"""Serving engine: continuous batching with raw or DCT-compressed KV.

Layers:
  * `make_steps` — jit-able pure step functions (prefill / decode / cache
    init) plus a `vec_pos` capability flag: transformer families thread a
    PER-SLOT position vector (B,) through decode, so every batch slot runs
    at its own depth.
  * `decode_step_compressed` — the KVCompress decode path: per layer each
    slot's new K/V goes into its own 8-token raw tail; full blocks flush to
    the int8 DCT store; attention streams the compressed store under each
    slot's causal horizon (core/kv_cache.py).
  * `Engine` — continuous-batching request server: admission queue, per-slot
    single-request prefill into a free slot, per-slot retirement on
    EOS/max_new, immediate re-admission. Live slots are never re-prefilled.
    `scheduler="static"` (and families without per-slot positions — the
    recurrent ones, where a scalar step index drives a state, not a cache)
    falls back to wave-at-a-time lock-step batching.

The compressed pool is the serving analogue of the paper's dynamically
allocated feature-map buffer: slots are occupied exactly as long as their
request lives, instead of the whole batch being provisioned for the slowest
request.

PAGED pool (`ServeConfig.pool_pages` / `page_budget_mb`): the dense per-slot
store becomes a shared page pool + per-slot block tables
(`core/kv_cache.py::PagedKVCache`) — the paper's block-granular buffer
allocation taken literally. The engine owns the allocator: a host-side free
list reserves each request's worst-case pages at admission (so a live slot
never stalls mid-flush), gates admission on FREE PAGES instead of free
slots, hands the decode jit a `(B,)` flush-page vector, and re-issues pages
on retirement. Admission splices only the prompt's own blocks through the
block table — nothing max_seq-sized is zero-filled — and greedy tokens stay
bitwise identical to the dense pool while pages are not exhausted.

Mesh-native serving: `ServeConfig.mesh` places the whole serve loop on a
(data x model) device mesh — batch slots (and every `KVSegment` plane of the
compressed pool) shard on `data`, attention heads on `model`, mirroring the
train-path param rules.  `serve_shardings` builds the explicit NamedShardings
and the Engine jits prefill / decode / cache-init / slot write / slot reset
with them, so the decode hot loop is compiled shard-local: each device owns
its slice of the slot pool the way the paper's banks own feature-map buffer
regions, and no step gathers the cache.  mesh=None degenerates to the
single-device behavior, bitwise.

MLA (deepseek-v2) keeps its raw latent cache: the latent IS a learned
compression (kv_lora 512 vs 2*128*128 per token = 64x); stacking a fixed DCT
basis on top of it measurably hurts (DESIGN.md §4) — `compressed=True` falls
back to raw for MLA and logs the fact.
"""
from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.codec import plan as plan_lib
from repro.core import kv_cache as kvc
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.api import ModelAPI
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Compressed-cache decode (GQA families)
# ---------------------------------------------------------------------------

def init_compressed_cache(cfg, batch: int, max_seq: int, keep: int = 4,
                          dtype=jnp.bfloat16, plan=None):
    return kvc.init_compressed_cache(cfg, batch, max_seq, keep=keep,
                                     dtype=dtype, plan=plan)


def _param_runs(cfg, params):
    """Stacked-layer param runs in absolute layer order: (stack, start, stop)."""
    if cfg.family == "moe":
        nk = cfg.first_k_dense
        runs = []
        if nk:
            runs.append((params["dense_layers"], 0, nk))
        runs.append((params["moe_layers"], nk, cfg.n_layers))
        return runs
    return [(params["layers"], 0, cfg.n_layers)]


def decode_step_compressed(
    params: Params,
    token: jax.Array,       # (B,)
    cache,                  # CompressedKVCache | PagedKVCache
    pos: jax.Array,         # (B,) per-slot positions (scalar broadcasts)
    cfg,
    *,
    kv_block: int = 1024,
    codec_backend: str | None = None,
    flush_page: jax.Array | None = None,  # (B,) page ids (paged pool only)
) -> tuple[jax.Array, Any]:
    """One-token decode against the DCT-compressed KV store.

    Every slot writes its token at its own `pos[b]` (own tail slot, own
    flush) and attends under its own watermark. The kept corner size is per
    layer: the cache's segments carry the materialized CompressionPlan, and
    the layer scan runs once per (segment x param-stack) intersection with
    that segment's static keep and backend. Attention and the block codec
    dispatch through repro.codec: the fused decompress+attend Pallas kernel
    on TPU, the pure-JAX scan elsewhere.

    With a `PagedKVCache`, `flush_page[b]` names the page the engine
    reserved for row b's flush THIS step (out-of-range id = no flush).  The
    block-table row update happens once here — every layer of a slot
    flushes the same block index, so the table is shared — and each layer's
    update/attend scatters/gathers through it.
    """
    assert cfg.attn_type == "gqa", "compressed cache is for GQA families"
    b_sz = token.shape[0]
    pos = kvc.as_pos_vec(pos, b_sz)
    paged = isinstance(cache, kvc.PagedKVCache)
    if paged:
        assert flush_page is not None, "paged decode needs the flush_page vector"
        nblocks = cache.block_table.shape[1]
        rows = jnp.arange(b_sz)
        flush_row = jnp.mod(pos, kvc.BLOCK) == kvc.BLOCK - 1
        # non-flushing rows are gated by blk=nblocks here (drop) and by
        # update_layer's own flush_row gate on the pool scatter — stray
        # page ids for such rows can land nowhere
        fp = kvc.as_pos_vec(flush_page, b_sz)
        blk = jnp.where(flush_row, pos // kvc.BLOCK, nblocks)
        block_table = cache.block_table.at[rows, blk].set(fp, mode="drop")
        block_table = sh.logical(block_table, "batch", None)
    else:
        assert flush_page is None, "flush_page is a paged-pool argument"
        fp = None
        block_table = None
    x = params["embed"][token][:, None, :].astype(params["embed"].dtype)
    positions = pos[:, None]  # (B, 1) per-row rope positions
    norm = T._norm(cfg)
    hd = cfg.resolved_head_dim
    runs = _param_runs(cfg, params)

    def make_layer_step(keep, backend):
        def layer_step(h, inp):
            p, lc = inp["p"], inp["cache"]
            hn = norm(p["ln1"], h)
            b, s, _ = hn.shape
            q = L.dense(p["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, hd)
            q = sh.attn_hint(q)  # heads on `model` (matches the cache specs)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new, v_new = L.gqa_project_kv(p["attn"], hn, positions, cfg)
            lc2 = kvc.update_layer(lc, k_new, v_new, pos, keep, backend=backend,
                                   flush_page=fp)
            attn = kvc.attend_auto(q, lc2, pos, keep, kv_block=kv_block,
                                   backend=backend, block_table=block_table)
            attn = sh.attn_hint(attn)
            h = h + L.dense(p["attn"]["wo"], attn.reshape(b, s, cfg.n_heads * hd))
            if "moe" in p:
                h = h + L.moe_ffn(p["moe"], norm(p["ln2"], h), cfg, dropless=True)
            else:
                h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
            return h, lc2

        return layer_step

    new_segments = []
    for seg in cache.segments:
        layer_step = make_layer_step(
            seg.keep, seg.backend if seg.backend is not None else codec_backend)
        seg_tree = seg.as_tree()
        parts = []
        for stack, ps, pe in runs:
            s0, s1 = max(seg.start, ps), min(seg.stop, pe)
            if s0 >= s1:
                continue
            pslice = jax.tree.map(lambda p: p[s0 - ps:s1 - ps], stack)
            cslice = jax.tree.map(lambda c: c[s0 - seg.start:s1 - seg.start],
                                  seg_tree)
            x, nc = jax.lax.scan(layer_step, x, {"p": pslice, "cache": cslice})
            parts.append(nc)
        new_tree = parts[0] if len(parts) == 1 else \
            jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        new_segments.append(seg.replace_arrays(new_tree))

    logits = T.unembed(params, x, cfg)[:, 0]
    if paged:
        return logits, kvc.PagedKVCache(tuple(new_segments), block_table)
    return logits, kvc.CompressedKVCache(tuple(new_segments))


def prefill_compressed(
    params: Params,
    tokens: jax.Array,
    cfg,
    max_seq: int,
    keep: int = 4,
    *,
    plan=None,
    lengths: jax.Array | None = None,  # (B,) valid prompt tokens per row
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, kvc.CompressedKVCache]:
    """Prefill into the compressed store: raw prefill then bulk-compress.

    `lengths[b]` is row b's true prompt length (right-padded prompts); it
    drives the per-row tail extraction — full 8-token blocks below the
    row's watermark are DCT-packed, the partial remainder lands raw in the
    row's tail ring. Defaults to the full token-array length for every row
    (the lock-step case).  Each plan segment bulk-compresses its own layer
    range with its own keep (legacy scalar `keep` => uniform plan).

    Only the prompt's own blocks run through the codec; the rest of the
    max_seq store is zero-filled directly, so admission cost scales with
    the prompt, not the pool depth.
    """
    assert cfg.attn_type == "gqa"
    plan = plan_lib.as_plan(plan, keep=keep)
    b, s = tokens.shape
    lengths = kvc.as_pos_vec(s if lengths is None else lengths, b)
    logits, raw = T.prefill(params, tokens, cfg, max_seq, cache_dtype=jnp.float32)
    nb_total = max_seq // kvc.BLOCK
    nb_used = min(-(-s // kvc.BLOCK), nb_total)  # blocks covering the prompt
    segments = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        kseg = pol.kv_keep
        comp = jax.vmap(
            lambda k, v: kvc.prefill_compress(k, v, kseg, pos=lengths,
                                              backend=pol.backend)
        )(raw["k"][start:stop, :, :nb_used * kvc.BLOCK],
          raw["v"][start:stop, :, :nb_used * kvc.BLOCK])  # vmap over layers
        if nb_used < nb_total:  # zero-fill the unwritten block range (axis 2)
            padb = lambda a: jnp.pad(
                a, ((0, 0), (0, 0), (0, nb_total - nb_used)) + ((0, 0),) * (a.ndim - 3))
            for key in ("packed_k", "scale_k", "packed_v", "scale_v"):
                comp[key] = padb(comp[key])
        segments.append(kvc.KVSegment(
            comp["packed_k"], comp["scale_k"], comp["packed_v"], comp["scale_v"],
            comp["tail_k"].astype(dtype), comp["tail_v"].astype(dtype),
            keep=kseg, start=start, stop=stop, backend=pol.backend,
        ))
    return logits, kvc.CompressedKVCache(tuple(segments))


def prefill_compressed_paged(
    params: Params,
    tokens: jax.Array,      # (1|B, bucket) right-padded prompt, bucket % 8 == 0
    cfg,
    *,
    plan=None,
    keep: int = 4,
    lengths: jax.Array | None = None,
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, tuple]:
    """Prefill one admission bucket into paged slot-update form.

    Unlike the dense path this never materializes (or zero-fills) a
    max_seq-sized store: the raw prefill cache is exactly the bucket, each
    plan segment bulk-compresses only the bucket's blocks, and the result
    is the per-segment update tree `paged_write_slot` scatters into the
    pool at engine-assigned page ids.  Admission cost is O(prompt), not
    O(max_seq) — the paper's "allocate the buffer the layer actually
    needs", applied to admission.
    """
    assert cfg.attn_type == "gqa"
    plan = plan_lib.as_plan(plan, keep=keep)
    b, s = tokens.shape
    assert s % kvc.BLOCK == 0, s
    lengths = kvc.as_pos_vec(s if lengths is None else lengths, b)
    logits, raw = T.prefill(params, tokens, cfg, s, cache_dtype=jnp.float32)
    update = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        kseg = pol.kv_keep
        comp = jax.vmap(
            lambda k, v: kvc.prefill_compress(k, v, kseg, pos=lengths,
                                              backend=pol.backend)
        )(raw["k"][start:stop], raw["v"][start:stop])  # vmap over layers
        comp["tail_k"] = comp["tail_k"].astype(dtype)
        comp["tail_v"] = comp["tail_v"].astype(dtype)
        update.append(comp)
    return logits, tuple(update)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    max_new_tokens: int = 64
    kv_compress: bool = False
    kv_keep: int = 4             # legacy scalar shim => CompressionPlan.uniform
    plan: Any = None             # CompressionPlan | spec string | int keep
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stops early
    kv_block: int = 1024
    codec_backend: str | None = None  # None = auto (repro.codec.dispatch)
    mesh: Any = None             # jax.sharding.Mesh: shard the serve loop on
                                 # (data, model); None = single-device path
    # Paged pool (the paper's dynamic feature-map buffer allocation): set
    # either knob to replace the dense per-slot store with a shared page
    # pool + block tables. `pool_pages` sizes the pool directly in 8-token
    # block groups; `page_budget_mb` solves the page count from a byte
    # budget using the plan's per-layer accounting (pool_pages wins when
    # both are set). Requires kv_compress on a GQA family with the
    # continuous scheduler.
    pool_pages: int | None = None
    page_budget_mb: float | None = None

    def resolved_plan(self) -> plan_lib.CompressionPlan:
        """The per-layer plan (scalar kv_keep is a uniform-plan shim)."""
        return plan_lib.as_plan(self.plan, keep=self.kv_keep,
                                backend=self.codec_backend)

    @property
    def paged(self) -> bool:
        return self.pool_pages is not None or self.page_budget_mb is not None

    def resolved_pool_pages(self, cfg) -> int:
        """Page count of the pool: explicit, or solved from the byte budget
        with the plan's per-layer page size (a page spans every layer, so
        its size is the summed per-layer block-group bytes)."""
        if self.pool_pages is not None:
            return int(self.pool_pages)
        assert self.page_budget_mb is not None
        page_b = self.resolved_plan().page_bytes(cfg)
        pages = int(self.page_budget_mb * 1e6 // page_b)
        if pages < 1:
            raise ValueError(
                f"page_budget_mb={self.page_budget_mb} holds no page "
                f"(one page = {page_b} B across {cfg.n_layers} layers)")
        return pages


def make_steps(api: ModelAPI, sc: ServeConfig):
    """(prefill_fn, decode_fn, cache_init, vec_pos). jit left to the caller.

    prefill_fn(params, tokens, lengths=None) -> (logits, cache)
    decode_fn(params, token, cache, pos)     -> (logits, cache)

    vec_pos=True marks families whose decode accepts a per-slot (B,)
    position vector — the requirement for continuous batching. Recurrent
    families (state caches, scalar step index) report False and are served
    wave-at-a-time. The classification lives on ArchConfig.vec_pos_decode
    (shared with ModelAPI.input_specs).
    """
    cfg = api.cfg
    use_comp = sc.kv_compress and cfg.attn_type == "gqa" and \
        cfg.resolved_head_dim % 8 == 0 and cfg.vec_pos_decode

    if sc.paged and not use_comp:
        raise ValueError(
            "paged KV pool needs kv_compress=True on a GQA family with "
            f"per-slot positions (arch {cfg.name}: attn_type={cfg.attn_type}, "
            f"vec_pos_decode={cfg.vec_pos_decode})")

    if use_comp and sc.paged:
        plan = sc.resolved_plan()
        n_pages = sc.resolved_pool_pages(cfg)

        def prefill_fn(params, tokens, lengths=None):
            return prefill_compressed_paged(params, tokens, cfg, plan=plan,
                                            lengths=lengths)

        def decode_fn(params, token, cache, pos, flush_page):
            return decode_step_compressed(params, token, cache, pos, cfg,
                                          kv_block=sc.kv_block,
                                          codec_backend=sc.codec_backend,
                                          flush_page=flush_page)

        cache_init = lambda b: kvc.init_paged_cache(cfg, b, sc.max_seq,
                                                    n_pages, plan=plan)
        return prefill_fn, decode_fn, cache_init, True

    if use_comp:
        plan = sc.resolved_plan()

        def prefill_fn(params, tokens, lengths=None):
            return prefill_compressed(params, tokens, cfg, sc.max_seq,
                                      plan=plan, lengths=lengths)

        def decode_fn(params, token, cache, pos):
            return decode_step_compressed(params, token, cache, pos, cfg,
                                          kv_block=sc.kv_block,
                                          codec_backend=sc.codec_backend)

        cache_init = lambda b: kvc.init_compressed_cache(cfg, b, sc.max_seq,
                                                         plan=plan)
        return prefill_fn, decode_fn, cache_init, True

    if cfg.vec_pos_decode:
        def prefill_fn(params, tokens, lengths=None):
            return T.prefill(params, tokens, cfg, sc.max_seq)

        def decode_fn(params, token, cache, pos):
            return T.decode_step(params, token, cache, pos, cfg, kv_block=sc.kv_block)

        cache_init = lambda b: api.init_cache(b, sc.max_seq)
        return prefill_fn, decode_fn, cache_init, True

    # recurrent families: prefill = teacher-forced decode of the prompt
    def prefill_fn(params, tokens, lengths=None):
        b, s = tokens.shape
        # cache activations must match the params' compute dtype
        cache = api.init_cache(b, sc.max_seq, dtype=params["embed"].dtype)

        def body(carry, t):
            cache = carry
            logits, cache = api.decode_step(params, tokens[:, t], cache, t)
            return cache, logits

        cache, logits_seq = jax.lax.scan(body, cache, jnp.arange(s))
        return jnp.moveaxis(logits_seq, 0, 1), cache  # (B, S, V)

    def decode_fn(params, token, cache, pos):
        return api.decode_step(params, token, cache, pos)

    cache_init = lambda b: api.init_cache(b, sc.max_seq)
    return prefill_fn, decode_fn, cache_init, False


# ---------------------------------------------------------------------------
# Mesh placement: explicit NamedShardings for every serve step
# ---------------------------------------------------------------------------

def serve_shardings(api: ModelAPI, params: Params, sc: ServeConfig,
                    batch: int, cache_init) -> dict[str, Any]:
    """Explicit NamedShardings for the serve step functions on `sc.mesh`.

    Placement mirrors the train-path rules: params via `param_specs` with
    fsdp=False (TP on `model`, replicated across `data` — serving reads
    weights every step, FSDP re-gathers would dominate decode), the KV pool
    via `cache_specs` (batch slots on `data`, kv heads on `model`, every
    `KVSegment` leaf included), and (B,) token/position vectors on `data`.
    Single-request admission tensors (batch 1) replicate — `fit_spec` drops
    non-dividing axes — and splice into the sharded pool through the
    slot-write scatter, so admitting one request never reshards the pool.
    """
    mesh = sc.mesh
    cfg = api.cfg
    axes = tuple(mesh.axis_names)
    ns = lambda spec: NamedSharding(mesh, spec)
    pool_shapes = jax.eval_shape(lambda: cache_init(batch))
    slot_shapes = jax.eval_shape(lambda: cache_init(1))
    return {
        "params": sh.param_shardings(params, mesh, fsdp=False),
        "rep": ns(P()),
        # (B,) per-slot token/position vectors ride the slot-pool data axes
        "vec": ns(sh.data_batch_spec(axes, 1, dim0=batch, mesh=mesh)),
        "pool": sh.cache_shardings(pool_shapes, cfg, mesh),
        "slot": sh.cache_shardings(slot_shapes, cfg, mesh),
        "tokens": ns(sh.data_batch_spec(axes, 2, dim0=batch, mesh=mesh)),
        "logits_decode": ns(sh.data_batch_spec(axes, 2, dim0=batch, mesh=mesh)),
        "logits_prefill": ns(sh.data_batch_spec(axes, 3, dim0=batch, mesh=mesh)),
        "logits_admit": ns(sh.data_batch_spec(axes, 3, dim0=1, mesh=mesh)),
    }


# ---------------------------------------------------------------------------
# Slot lifecycle helpers (jit-able; work on any cache pytree, batch axis 1)
# ---------------------------------------------------------------------------

def cache_write_slot(cache, slot_cache, slot: jax.Array):
    """Copy a single-request (batch=1) cache into slot `slot` of the pool."""
    return jax.tree.map(
        lambda c, s: jax.lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), slot, axis=1),
        cache, slot_cache,
    )


def cache_reset_slot(cache, slot: jax.Array):
    """Zero one slot's planes on retirement (any cache pytree)."""
    return kvc.cache_reset_slot(cache, slot)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    """Continuous-batching request server over a shared KV pool.

    Slots are independent: each live request has its own position, so a
    retired slot is re-admitted immediately from the queue while its
    neighbours keep decoding — no request waits for the wave's slowest.
    Admission prefills ONE request (prompt bucketed to a multiple of 8 to
    bound jit retraces) and splices its cache into the free slot; live
    slots are never re-prefilled.

    Sampling order is explicit: the first output token is sampled from the
    prefill logits at the prompt's last position; a decode step only runs
    while some slot still needs tokens (a request whose max_new is 1
    finishes at admission without a decode step).

    `scheduler="static"` restores wave-at-a-time lock-step batching
    (right-aligned prompts, one scalar position) — the baseline the
    throughput benchmark compares against. Families without per-slot
    position support (recurrent state caches) always use it.
    """

    def __init__(self, api: ModelAPI, params: Params, sc: ServeConfig, batch: int,
                 seed: int = 0, scheduler: str = "continuous"):
        assert scheduler in ("continuous", "static"), scheduler
        self.api = api
        self.sc = sc
        self.batch = batch
        self.rng = jax.random.PRNGKey(seed)
        prefill_fn, decode_fn, cache_init, vec_pos = make_steps(api, sc)
        self.vec_pos = vec_pos
        self.scheduler = scheduler if vec_pos else "static"
        self.paged = sc.paged
        if self.paged:
            if self.scheduler != "continuous":
                raise ValueError("paged KV pool requires the continuous "
                                 "scheduler (pages follow per-slot lifetimes)")
            # host-side page allocator: the free list IS the allocation
            # policy — the device only ever sees page ids it was handed
            self._n_pages = sc.resolved_pool_pages(api.cfg)
            self._free_pages = list(range(self._n_pages))
            self._slot_pages: list[list[int]] = [[] for _ in range(batch)]
        self._cache_init_raw = cache_init  # un-jitted: pool accounting
        if sc.mesh is None:
            self._prefill = jax.jit(prefill_fn)
            self._decode = jax.jit(decode_fn)
            self._cache_init = cache_init
            if self.paged:
                self._write = jax.jit(kvc.paged_write_slot)
                self._reset = jax.jit(kvc.paged_reset_slot)
            else:
                self._write = jax.jit(cache_write_slot)
                self._reset = jax.jit(cache_reset_slot)
        elif self.paged:
            # paged + mesh: pin the decode hot loop (params / pool / (B,)
            # vectors) with explicit shardings; admission ops are per-request
            # and bucket-shaped, so they jit with the pool OUTPUT pinned and
            # inputs left to placement propagation (batch-1 tensors are tiny)
            shd = serve_shardings(api, params, sc, batch, cache_init)
            params = jax.device_put(params, shd["params"])
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shd["params"], shd["vec"], shd["pool"],
                              shd["vec"], shd["vec"]),
                out_shardings=(shd["logits_decode"], shd["pool"]),
            )
            self._prefill = jax.jit(prefill_fn)
            pool_init = jax.jit(lambda: cache_init(batch),
                                out_shardings=shd["pool"])
            self._cache_init = lambda b: pool_init()
            self._write = jax.jit(kvc.paged_write_slot,
                                  out_shardings=shd["pool"])
            self._reset = jax.jit(kvc.paged_reset_slot,
                                  out_shardings=shd["pool"])
        else:
            shd = serve_shardings(api, params, sc, batch, cache_init)
            # place params once; the jits below pin the same shardings, so no
            # per-call retransfer (and a launcher device_put is a no-op)
            params = jax.device_put(params, shd["params"])
            # static waves drive decode with one scalar position; continuous
            # threads the per-slot (B,) vector on the data axes
            pos_sh = shd["vec"] if self.scheduler == "continuous" else shd["rep"]
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shd["params"], shd["vec"], shd["pool"], pos_sh),
                out_shardings=(shd["logits_decode"], shd["pool"]),
            )
            if self.scheduler == "continuous":
                # admission: one request (batch 1, replicated) -> slot cache
                self._prefill = jax.jit(
                    prefill_fn,
                    in_shardings=(shd["params"], shd["rep"], shd["rep"]),
                    out_shardings=(shd["logits_admit"], shd["slot"]),
                )
            else:
                # lock-step wave: the full (B, S) prompt block is data-sharded
                self._prefill = jax.jit(
                    prefill_fn,
                    in_shardings=(shd["params"], shd["tokens"]),
                    out_shardings=(shd["logits_prefill"], shd["pool"]),
                )
            pool_init = jax.jit(lambda: cache_init(batch),
                                out_shardings=shd["pool"])
            self._cache_init = lambda b: pool_init()
            self._write = jax.jit(
                cache_write_slot,
                in_shardings=(shd["pool"], shd["slot"], shd["rep"]),
                out_shardings=shd["pool"],
            )
            self._reset = jax.jit(
                cache_reset_slot,
                in_shardings=(shd["pool"], shd["rep"]),
                out_shardings=shd["pool"],
            )
        self.params = params
        self.stats = {"requests": 0, "tokens_out": 0, "steps": 0,
                      "prefill_s": 0.0, "decode_s": 0.0,
                      "slot_steps_live": 0, "slot_steps_total": 0,
                      "peak_live_slots": 0, "admit_blocked_on_pages": 0,
                      "peak_pages_in_use": 0}

    # ------------------------------------------------------------------ util
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def slot_utilization(self) -> float:
        """Fraction of decode slot-steps spent on live requests."""
        return self.stats["slot_steps_live"] / max(self.stats["slot_steps_total"], 1)

    def kv_pool_stats(self) -> dict:
        """Analytic footprint of this engine's KV pool: total bytes and the
        per-device slice under `sc.mesh` (the banked-buffer accounting —
        what one device/bank actually holds). No allocation: eval_shape.

        On a paged engine the report adds the allocator's view: pool pages,
        page bytes, pages currently and peak in use, and slots-per-GB (how
        many concurrent slots one GB of pool supports at this geometry —
        the headline number the paged pool improves)."""
        shapes = jax.eval_shape(lambda: self._cache_init_raw(self.batch))
        total = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(shapes))
        mesh = self.sc.mesh
        per_device = float(total) if mesh is None else sh.per_device_bytes(
            shapes, sh.cache_specs(shapes, self.api.cfg, mesh), mesh)
        out = {"kv_pool_bytes": int(total),
               "kv_bytes_per_device": per_device,
               "slots_per_gb": self.batch / max(total / 1e9, 1e-12)}
        if self.paged:
            out.update(
                pool_pages=self._n_pages,
                page_bytes=self.sc.resolved_plan().page_bytes(self.api.cfg),
                pages_in_use=self._n_pages - len(self._free_pages),
                peak_pages_in_use=self.stats["peak_pages_in_use"],
            )
        return out

    # ------------------------------------------------------------------ API
    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve every request to completion; returns them in input order.

        The caller's list is never mutated; the Request objects are (their
        out_tokens/done fields fill in as slots retire).
        """
        queue = list(requests)
        # the ambient mesh context activates the model-internal shard hints
        # (sharding.logical / attn_hint) while the jits' explicit in/out
        # NamedShardings pin the step boundaries
        ctx = mesh_lib.use_mesh(self.sc.mesh) if self.sc.mesh is not None \
            else contextlib.nullcontext()
        with ctx:
            if self.scheduler == "static":
                for w0 in range(0, len(queue), self.batch):
                    self._run_wave(queue[w0:w0 + self.batch])
            else:
                self._run_continuous(queue)
        self.stats["requests"] += len(queue)
        return queue

    # ------------------------------------------------- continuous scheduler
    def _pages_needed(self, r: Request) -> int:
        """Worst-case pages request `r` can flush over its whole lifetime.

        Positions written span [0, min(plen + max_new - 1, max_seq)); a page
        is consumed per completed 8-token block, so reserving this many at
        admission guarantees a live slot never stalls mid-decode for a page
        (slot preemption is the ROADMAP follow-on that would relax this).
        """
        horizon = min(len(r.prompt) + r.max_new - 1, self.sc.max_seq)
        return horizon // kvc.BLOCK

    def _release_pages(self, slot: int) -> None:
        self._free_pages.extend(self._slot_pages[slot])
        self._slot_pages[slot] = []

    def _admit(self, r: Request, cache, slot: int):
        """Prefill one request (batch=1) and splice it into `slot`."""
        plen = len(r.prompt)
        bucket = max(kvc.BLOCK, -(-plen // kvc.BLOCK) * kvc.BLOCK)
        if bucket > self.sc.max_seq:
            raise ValueError(
                f"prompt of {plen} tokens needs a {bucket}-token bucket "
                f"> max_seq={self.sc.max_seq}")
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = r.prompt
        logits, slot_cache = self._prefill(
            self.params, jnp.asarray(padded), jnp.asarray([plen], jnp.int32))
        if self.paged:
            # splice through the block table: the prompt's full blocks land
            # in the slot's reserved pages; padding blocks of the bucket are
            # dropped (out-of-range page id); the partial block stays in the
            # tail ring. Nothing max_seq-sized is written.
            prompt_blocks = plen // kvc.BLOCK
            pages = self._slot_pages[slot]
            page_ids = np.full(bucket // kvc.BLOCK, self._n_pages, np.int32)
            page_ids[:prompt_blocks] = pages[:prompt_blocks]
            row = np.zeros(self.sc.max_seq // kvc.BLOCK, np.int32)
            row[:prompt_blocks] = pages[:prompt_blocks]
            cache = self._write(cache, slot_cache, jnp.int32(slot),
                                jnp.asarray(page_ids), jnp.asarray(row))
        else:
            cache = self._write(cache, slot_cache, jnp.int32(slot))
        first = int(np.asarray(self._sample(logits[:, plen - 1]))[0])
        return first, cache

    def _run_continuous(self, queue: list[Request]) -> None:
        slots: list[Request | None] = [None] * self.batch
        pos = np.zeros(self.batch, np.int32)
        token = np.zeros(self.batch, np.int32)
        cache = self._cache_init(self.batch)
        qi = 0
        while True:
            # ---- admission: fill free slots from the queue (paged pools
            # additionally gate on free pages, FCFS) ----------------------
            for i in range(self.batch):
                if slots[i] is not None or qi >= len(queue):
                    continue
                r = queue[qi]
                if self.paged:
                    need = self._pages_needed(r)
                    if need > self._n_pages:
                        raise ValueError(
                            f"request {r.uid} needs {need} pages > pool of "
                            f"{self._n_pages} (raise pool_pages/page_budget_mb"
                            " or lower max_new)")
                    if need > len(self._free_pages):
                        # blocked on pages, not slots: keep decoding; the
                        # next retirement frees pages and re-tries (FCFS, so
                        # later small requests don't starve this one)
                        self.stats["admit_blocked_on_pages"] += 1
                        break
                    self._slot_pages[i] = [self._free_pages.pop()
                                           for _ in range(need)]
                    used = self._n_pages - len(self._free_pages)
                    self.stats["peak_pages_in_use"] = max(
                        self.stats["peak_pages_in_use"], used)
                qi += 1
                t0 = time.perf_counter()
                try:
                    first, cache = self._admit(r, cache, i)
                except Exception:
                    if self.paged:
                        # admission failed (e.g. prompt bucket > max_seq):
                        # the reservation must not leak out of the pool
                        self._release_pages(i)
                    raise
                self.stats["prefill_s"] += time.perf_counter() - t0
                r.out_tokens.append(first)
                self.stats["tokens_out"] += 1
                plen = len(r.prompt)
                if first == self.sc.eos_id or len(r.out_tokens) >= r.max_new \
                        or plen >= self.sc.max_seq:
                    r.done = True  # finished at admission — no decode step
                    cache = self._reset(cache, jnp.int32(i))
                    if self.paged:
                        self._release_pages(i)
                else:
                    slots[i] = r
                    pos[i] = plen
                    token[i] = first
            live = [i for i in range(self.batch) if slots[i] is not None]
            if not live:
                if qi >= len(queue):
                    return
                continue  # everything retired at admission; admit more
            self.stats["peak_live_slots"] = max(
                self.stats["peak_live_slots"], len(live))
            # ---- one decode step over the pool, per-slot positions -------
            t0 = time.perf_counter()
            if self.paged:
                # hand each flushing row its reserved page; every other row
                # gets an out-of-range id the device scatter drops
                fp = np.full(self.batch, self._n_pages, np.int32)
                for i in live:
                    if pos[i] % kvc.BLOCK == kvc.BLOCK - 1:
                        fp[i] = self._slot_pages[i][pos[i] // kvc.BLOCK]
                logits, cache = self._decode(self.params, jnp.asarray(token),
                                             cache, jnp.asarray(pos),
                                             jnp.asarray(fp))
            else:
                logits, cache = self._decode(self.params, jnp.asarray(token),
                                             cache, jnp.asarray(pos))
            nxt = np.asarray(self._sample(logits))
            self.stats["decode_s"] += time.perf_counter() - t0
            self.stats["steps"] += 1
            self.stats["slot_steps_total"] += self.batch
            self.stats["slot_steps_live"] += len(live)
            for i in live:
                r = slots[i]
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                self.stats["tokens_out"] += 1
                pos[i] += 1
                token[i] = tok
                if tok == self.sc.eos_id or len(r.out_tokens) >= r.max_new \
                        or pos[i] >= self.sc.max_seq:
                    r.done = True
                    slots[i] = None  # retire; slot re-admits next iteration
                    pos[i] = 0
                    token[i] = 0
                    cache = self._reset(cache, jnp.int32(i))
                    if self.paged:
                        self._release_pages(i)

    # ----------------------------------------------------- static scheduler
    def _run_wave(self, wave: list[Request]) -> None:
        """Lock-step wave: right-aligned prompts, one scalar position."""
        assert len(wave) <= self.batch
        # every wave request is live from prefill until it retires
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            len(wave))
        slots = list(wave) + [
            Request(uid=-1, prompt=np.zeros(kvc.BLOCK, np.int32), max_new=1)
            for _ in range(self.batch - len(wave))
        ]
        plen = max(kvc.BLOCK, max(len(r.prompt) for r in slots))
        prompts = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(slots):
            prompts[i, plen - len(r.prompt):] = r.prompt  # right-align

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        self.stats["prefill_s"] += time.perf_counter() - t0

        # explicit ordering: sample from prefill -> append/check -> only then
        # decode. If every request finishes on its first token, no decode
        # step runs and no logits are sampled twice.
        token = self._sample(logits[:, -1])
        max_new = max(r.max_new for r in wave)
        done = np.zeros(self.batch, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            tok_np = np.asarray(token)
            for i, r in enumerate(slots):
                if r.uid >= 0 and not r.done:
                    tok = int(tok_np[i])
                    r.out_tokens.append(tok)
                    self.stats["tokens_out"] += 1
                    if tok == self.sc.eos_id or len(r.out_tokens) >= r.max_new:
                        r.done = True
                done[i] = r.done or r.uid < 0
            if done.all():
                break
            if plen + step >= self.sc.max_seq:
                # context exhausted: no slot can write another token — retire
                # the wave truncated (mirrors the continuous pos >= max_seq
                # guard) instead of silently dropping K/V writes
                for r in slots:
                    if r.uid >= 0:
                        r.done = True
                break
            logits_step, cache = self._decode(self.params, token, cache,
                                              jnp.int32(plen + step))
            token = self._sample(logits_step)
            self.stats["steps"] += 1
            self.stats["slot_steps_total"] += self.batch
            self.stats["slot_steps_live"] += int((~done).sum())
        self.stats["decode_s"] += time.perf_counter() - t0
