"""Serving engine: batched prefill + decode with raw or DCT-compressed KV.

Layers:
  * `make_prefill` / `make_decode` — jit-able pure step functions (these are
    what the multi-pod dry-run lowers for the decode_* shapes).
  * `decode_step_compressed` — the KVCompress decode path: per layer the new
    token's K/V goes into an 8-token raw tail; full blocks are flushed to the
    int8 DCT store; attention streams the compressed store (core/kv_cache.py).
  * `Engine` — static-batch request server: admits up to `batch` requests,
    prefills the batch, decodes until every slot hits EOS/max_new, retires.

MLA (deepseek-v2) keeps its raw latent cache: the latent IS a learned
compression (kv_lora 512 vs 2*128*128 per token = 64x); stacking a fixed DCT
basis on top of it measurably hurts (DESIGN.md §4) — `compressed=True` falls
back to raw for MLA and logs the fact.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kv_cache as kvc
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.api import ModelAPI

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Compressed-cache decode (GQA families)
# ---------------------------------------------------------------------------

def init_compressed_cache(cfg, batch: int, max_seq: int, keep: int = 4,
                          dtype=jnp.bfloat16):
    return kvc.init_compressed_cache(cfg, batch, max_seq, keep=keep, dtype=dtype)


def decode_step_compressed(
    params: Params,
    token: jax.Array,       # (B,)
    cache: kvc.CompressedKVCache,
    pos: jax.Array,         # scalar
    cfg,
    *,
    kv_block: int = 1024,
    codec_backend: str | None = None,
) -> tuple[jax.Array, kvc.CompressedKVCache]:
    """One-token decode against the DCT-compressed KV store.

    Attention and the block codec dispatch through repro.codec: the fused
    decompress+attend Pallas kernel on TPU, the pure-JAX scan elsewhere.
    """
    assert cfg.attn_type == "gqa", "compressed cache is for GQA families"
    keep = cache.keep
    x = params["embed"][token][:, None, :].astype(params["embed"].dtype)
    positions = jnp.full((1, 1), pos, jnp.int32)
    norm = T._norm(cfg)
    hd = cfg.resolved_head_dim

    def layer_step(h, inp):
        p, lc = inp["p"], inp["cache"]
        hn = norm(p["ln1"], h)
        b, s, _ = hn.shape
        q = L.dense(p["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k_new, v_new = L.gqa_project_kv(p["attn"], hn, positions, cfg)
        lc2 = kvc.update_layer(lc, k_new, v_new, pos, keep)
        attn = kvc.attend_auto(q, lc2, pos, keep, kv_block=kv_block,
                               backend=codec_backend)
        h = h + L.dense(p["attn"]["wo"], attn.reshape(b, s, cfg.n_heads * hd))
        if "moe" in p:
            h = h + L.moe_ffn(p["moe"], norm(p["ln2"], h), cfg, dropless=True)
        else:
            h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
        return h, lc2

    cache_tree = {
        "packed_k": cache.packed_k, "scale_k": cache.scale_k,
        "packed_v": cache.packed_v, "scale_v": cache.scale_v,
        "tail_k": cache.tail_k, "tail_v": cache.tail_v,
    }

    def run(x, stacked, ct):
        return jax.lax.scan(layer_step, x, {"p": stacked, "cache": ct})

    if cfg.family == "moe":
        nk = cfg.first_k_dense
        parts = []
        if nk:
            ct_d = jax.tree.map(lambda c: c[:nk], cache_tree)
            x, nc_d = run(x, params["dense_layers"], ct_d)
            parts.append(nc_d)
        ct_m = jax.tree.map(lambda c: c[nk:], cache_tree)
        x, nc_m = run(x, params["moe_layers"], ct_m)
        parts.append(nc_m)
        new_tree = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts) \
            if len(parts) > 1 else parts[0]
    else:
        x, new_tree = run(x, params["layers"], cache_tree)

    logits = T.unembed(params, x, cfg)[:, 0]
    new_cache = kvc.CompressedKVCache(
        new_tree["packed_k"], new_tree["scale_k"],
        new_tree["packed_v"], new_tree["scale_v"],
        new_tree["tail_k"], new_tree["tail_v"], keep,
    )
    return logits, new_cache


def prefill_compressed(
    params: Params,
    tokens: jax.Array,
    cfg,
    max_seq: int,
    keep: int = 4,
    *,
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, kvc.CompressedKVCache]:
    """Prefill into the compressed store: raw prefill then bulk-compress.

    Prompt K/V of all full 8-token blocks is DCT-packed; the remainder
    (< 8 tokens) lands in the raw tail.
    """
    assert cfg.attn_type == "gqa"
    logits, raw = T.prefill(params, tokens, cfg, max_seq, cache_dtype=jnp.float32)
    s = tokens.shape[1]
    s_full = (s // kvc.BLOCK) * kvc.BLOCK
    comp = jax.vmap(lambda k, v: kvc.prefill_compress(k, v, keep))(
        raw["k"], raw["v"]
    )  # vmap over layers
    # tail: the trailing partial block (positions s_full .. s)
    tail_src_k = jax.lax.dynamic_slice_in_dim(raw["k"], s_full, kvc.BLOCK, 2) \
        if s_full + kvc.BLOCK <= raw["k"].shape[2] else raw["k"][:, :, -kvc.BLOCK:]
    tail_src_v = jax.lax.dynamic_slice_in_dim(raw["v"], s_full, kvc.BLOCK, 2) \
        if s_full + kvc.BLOCK <= raw["v"].shape[2] else raw["v"][:, :, -kvc.BLOCK:]
    cache = kvc.CompressedKVCache(
        packed_k=comp["packed_k"], scale_k=comp["scale_k"],
        packed_v=comp["packed_v"], scale_v=comp["scale_v"],
        tail_k=tail_src_k.astype(dtype), tail_v=tail_src_v.astype(dtype),
        keep=keep,
    )
    return logits, cache


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    max_new_tokens: int = 64
    kv_compress: bool = False
    kv_keep: int = 4
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stops early
    kv_block: int = 1024
    codec_backend: str | None = None  # None = auto (repro.codec.dispatch)


def make_steps(api: ModelAPI, sc: ServeConfig):
    """(prefill_fn, decode_fn, cache_init). jit left to the caller/Engine."""
    cfg = api.cfg
    use_comp = sc.kv_compress and cfg.attn_type == "gqa" and \
        cfg.resolved_head_dim % 8 == 0 and cfg.family in ("dense", "moe", "vlm")

    if use_comp:
        def prefill_fn(params, tokens):
            return prefill_compressed(params, tokens, cfg, sc.max_seq, sc.kv_keep)

        def decode_fn(params, token, cache, pos):
            return decode_step_compressed(params, token, cache, pos, cfg,
                                          kv_block=sc.kv_block,
                                          codec_backend=sc.codec_backend)

        cache_init = lambda b: kvc.init_compressed_cache(cfg, b, sc.max_seq, sc.kv_keep)
        return prefill_fn, decode_fn, cache_init

    if cfg.family in ("dense", "moe", "vlm"):
        def prefill_fn(params, tokens):
            return T.prefill(params, tokens, cfg, sc.max_seq)

        def decode_fn(params, token, cache, pos):
            return T.decode_step(params, token, cache, pos, cfg, kv_block=sc.kv_block)

        cache_init = lambda b: api.init_cache(b, sc.max_seq)
        return prefill_fn, decode_fn, cache_init

    # recurrent families: prefill = teacher-forced decode of the prompt
    def prefill_fn(params, tokens):
        b, s = tokens.shape
        # cache activations must match the params' compute dtype
        cache = api.init_cache(b, sc.max_seq, dtype=params["embed"].dtype)

        def body(carry, t):
            cache = carry
            logits, cache = api.decode_step(params, tokens[:, t], cache, t)
            return cache, logits

        cache, logits_seq = jax.lax.scan(body, cache, jnp.arange(s))
        return jnp.moveaxis(logits_seq, 0, 1), cache  # (B, S, V)

    def decode_fn(params, token, cache, pos):
        return api.decode_step(params, token, cache, pos)

    cache_init = lambda b: api.init_cache(b, sc.max_seq)
    return prefill_fn, decode_fn, cache_init


# ---------------------------------------------------------------------------
# Static-batch engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    """Admit up to `batch` requests, prefill once, decode lock-step.

    Prompts are right-aligned to a common length (left-padded with 0; the
    causal mask plus identical lengths keep semantics exact for the batch).
    Sampling: greedy or temperature softmax with a fixed seed per engine.
    """

    def __init__(self, api: ModelAPI, params: Params, sc: ServeConfig, batch: int,
                 seed: int = 0):
        self.api = api
        self.params = params
        self.sc = sc
        self.batch = batch
        self.rng = jax.random.PRNGKey(seed)
        prefill_fn, decode_fn, cache_init = make_steps(api, sc)
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self.stats = {"requests": 0, "tokens_out": 0, "steps": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, requests: list[Request]) -> list[Request]:
        assert len(requests) <= self.batch
        while len(requests) < self.batch:  # pad batch with a dummy slot
            requests.append(Request(uid=-1, prompt=np.zeros(8, np.int32), max_new=1))
        plen = max(len(r.prompt) for r in requests)
        plen = max(8, plen)
        prompts = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # right-align

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        self.stats["prefill_s"] += time.perf_counter() - t0

        token = self._sample(logits[:, -1])
        max_new = max(r.max_new for r in requests)
        done = np.zeros(self.batch, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            for i, r in enumerate(requests):
                if r.uid >= 0 and not r.done:
                    tok = int(token[i])
                    r.out_tokens.append(tok)
                    if tok == self.sc.eos_id or len(r.out_tokens) >= r.max_new:
                        r.done = True
                done[i] = r.done or r.uid < 0
            self.stats["tokens_out"] += int((~done).sum()) + int(done.sum() * 0)
            if done.all():
                break
            pos = jnp.int32(plen + step)
            logits_step, cache = self._decode(self.params, token, cache, pos)
            token = self._sample(logits_step)
            self.stats["steps"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["requests"] += sum(1 for r in requests if r.uid >= 0)
        return [r for r in requests if r.uid >= 0]
