"""Serving engine: continuous batching with raw or DCT-compressed KV.

Layers:
  * `make_steps` — jit-able pure step functions (prefill / decode / cache
    init) plus a `vec_pos` capability flag: transformer families thread a
    PER-SLOT position vector (B,) through decode, so every batch slot runs
    at its own depth.
  * `decode_step_compressed` — the KVCompress decode path: per layer each
    slot's new K/V goes into its own 8-token raw tail; full blocks flush to
    the int8 DCT store; attention streams the compressed store under each
    slot's causal horizon (core/kv_cache.py).
  * `Engine` — continuous-batching request server: admission queue, per-slot
    single-request prefill into a free slot, per-slot retirement on
    EOS/max_new, immediate re-admission. Live slots are never re-prefilled.
    `scheduler="static"` (and families without per-slot positions — the
    recurrent ones, where a scalar step index drives a state, not a cache)
    falls back to wave-at-a-time lock-step batching.

The compressed pool is the serving analogue of the paper's dynamically
allocated feature-map buffer: slots are occupied exactly as long as their
request lives, instead of the whole batch being provisioned for the slowest
request.

PAGED pool (`ServeConfig.pool_pages` / `page_budget_mb`): the dense per-slot
store becomes a shared page pool + per-slot block tables
(`core/kv_cache.py::PagedKVCache`) — the paper's block-granular buffer
allocation taken literally. The engine owns the allocator: a host-side free
list reserves each request's worst-case pages at admission (so a live slot
never stalls mid-flush), gates admission on FREE PAGES instead of free
slots, hands the decode jit a `(B,)` flush-page vector, and re-issues pages
on retirement. Admission splices only the prompt's own blocks through the
block table — nothing max_seq-sized is zero-filled — and greedy tokens stay
bitwise identical to the dense pool while pages are not exhausted.

Mesh-native serving: `ServeConfig.mesh` places the whole serve loop on a
(data x model) device mesh — batch slots (and every `KVSegment` plane of the
compressed pool) shard on `data`, attention heads on `model`, mirroring the
train-path param rules.  `serve_shardings` builds the explicit NamedShardings
and the Engine jits prefill / decode / cache-init / slot write / slot reset
with them, so the decode hot loop is compiled shard-local: each device owns
its slice of the slot pool the way the paper's banks own feature-map buffer
regions, and no step gathers the cache.  mesh=None degenerates to the
single-device behavior, bitwise.

MLA (deepseek-v2) keeps its raw latent cache: the latent IS a learned
compression (kv_lora 512 vs 2*128*128 per token = 64x); stacking a fixed DCT
basis on top of it measurably hurts (DESIGN.md §4) — `compressed=True` falls
back to raw for MLA and logs the fact.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.codec import plan as plan_lib
from repro.core import kv_cache as kvc
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.api import ModelAPI
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh
from repro.serve import pipeline as pl
from repro.serve import tiering

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Compressed-cache decode (GQA families)
# ---------------------------------------------------------------------------

def init_compressed_cache(cfg, batch: int, max_seq: int, keep: int = 4,
                          dtype=jnp.bfloat16, plan=None):
    return kvc.init_compressed_cache(cfg, batch, max_seq, keep=keep,
                                     dtype=dtype, plan=plan)


def _param_runs(cfg, params):
    """Stacked-layer param runs in absolute layer order: (stack, start, stop)."""
    if cfg.family == "moe":
        nk = cfg.first_k_dense
        runs = []
        if nk:
            runs.append((params["dense_layers"], 0, nk))
        runs.append((params["moe_layers"], nk, cfg.n_layers))
        return runs
    return [(params["layers"], 0, cfg.n_layers)]


def decode_step_compressed(
    params: Params,
    token: jax.Array,       # (B,)
    cache,                  # CompressedKVCache | PagedKVCache
    pos: jax.Array,         # (B,) per-slot positions (scalar broadcasts)
    cfg,
    *,
    kv_block: int = 1024,
    codec_backend: str | None = None,
    flush_page: jax.Array | None = None,  # (B,) page ids (paged pool only)
    attend_blocks: int | None = None,     # static table-slice width (paged)
    pages_per_tile: int = 8,              # paged kernel G-page tile width
) -> tuple[jax.Array, Any]:
    """One-token decode against the DCT-compressed KV store.

    Every slot writes its token at its own `pos[b]` (own tail slot, own
    flush) and attends under its own watermark. The kept corner size is per
    layer: the cache's segments carry the materialized CompressionPlan, and
    the layer scan runs once per (segment x param-stack) intersection with
    that segment's static keep and backend. Attention and the block codec
    dispatch through repro.codec: the fused decompress+attend Pallas kernel
    on TPU, the pure-JAX scan elsewhere.

    With a `PagedKVCache`, `flush_page[b]` names the page the engine
    reserved for row b's flush THIS step (out-of-range id = no flush).  The
    block-table row update happens once here — every layer of a slot
    flushes the same block index, so the table is shared — and each layer's
    update/attend scatters/gathers through it.  `attend_blocks` (the
    decode-bucket ladder pick, in table entries) statically slices the
    table the ATTEND sees to the occupied context; the flush update and
    the cache's stored table always stay full-width.
    """
    assert cfg.attn_type == "gqa", "compressed cache is for GQA families"
    b_sz = token.shape[0]
    pos = kvc.as_pos_vec(pos, b_sz)
    paged = isinstance(cache, kvc.PagedKVCache)
    if paged:
        assert flush_page is not None, "paged decode needs the flush_page vector"
        nblocks = cache.block_table.shape[1]
        rows = jnp.arange(b_sz)
        flush_row = jnp.mod(pos, kvc.BLOCK) == kvc.BLOCK - 1
        # non-flushing rows are gated by blk=nblocks here (drop) and by
        # update_layer's own flush_row gate on the pool scatter — stray
        # page ids for such rows can land nowhere
        fp = kvc.as_pos_vec(flush_page, b_sz)
        blk = jnp.where(flush_row, pos // kvc.BLOCK, nblocks)
        block_table = cache.block_table.at[rows, blk].set(fp, mode="drop")
        block_table = sh.logical(block_table, "batch", None)
        att_table = kvc.table_view(block_table, attend_blocks)
    else:
        assert flush_page is None, "flush_page is a paged-pool argument"
        fp = None
        block_table = None
        att_table = None
    x = params["embed"][token][:, None, :].astype(params["embed"].dtype)
    positions = pos[:, None]  # (B, 1) per-row rope positions
    norm = T._norm(cfg)
    hd = cfg.resolved_head_dim
    runs = _param_runs(cfg, params)

    def make_layer_step(keep, backend, codec):
        def layer_step(h, inp):
            p, lc = inp["p"], inp["cache"]
            hn = norm(p["ln1"], h)
            b, s, _ = hn.shape
            q = L.dense(p["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, hd)
            q = sh.attn_hint(q)  # heads on `model` (matches the cache specs)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k_new, v_new = L.gqa_project_kv(p["attn"], hn, positions, cfg)
            lc2 = kvc.update_layer(lc, k_new, v_new, pos, keep, backend=backend,
                                   flush_page=fp, codec=codec)
            attn = kvc.attend_auto(q, lc2, pos, keep, kv_block=kv_block,
                                   backend=backend, block_table=att_table,
                                   pages_per_tile=pages_per_tile, codec=codec)
            attn = sh.attn_hint(attn)
            h = h + L.dense(p["attn"]["wo"], attn.reshape(b, s, cfg.n_heads * hd))
            if "moe" in p:
                h = h + L.moe_ffn(p["moe"], norm(p["ln2"], h), cfg, dropless=True)
            else:
                h = h + L.mlp(p["mlp"], norm(p["ln2"], h), cfg)
            return h, lc2

        return layer_step

    new_segments = []
    for seg in cache.segments:
        layer_step = make_layer_step(
            seg.keep, seg.backend if seg.backend is not None else codec_backend,
            seg.codec)
        seg_tree = seg.as_tree()
        parts = []
        for stack, ps, pe in runs:
            s0, s1 = max(seg.start, ps), min(seg.stop, pe)
            if s0 >= s1:
                continue
            pslice = jax.tree.map(lambda p: p[s0 - ps:s1 - ps], stack)
            cslice = jax.tree.map(lambda c: c[s0 - seg.start:s1 - seg.start],
                                  seg_tree)
            x, nc = jax.lax.scan(layer_step, x, {"p": pslice, "cache": cslice})
            parts.append(nc)
        new_tree = parts[0] if len(parts) == 1 else \
            jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        new_segments.append(seg.replace_arrays(new_tree))

    logits = T.unembed(params, x, cfg)[:, 0]
    if paged:
        return logits, kvc.PagedKVCache(tuple(new_segments), block_table)
    return logits, kvc.CompressedKVCache(tuple(new_segments))


def prefill_compressed(
    params: Params,
    tokens: jax.Array,
    cfg,
    max_seq: int,
    keep: int = 4,
    *,
    plan=None,
    lengths: jax.Array | None = None,  # (B,) valid prompt tokens per row
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, kvc.CompressedKVCache]:
    """Prefill into the compressed store: raw prefill then bulk-compress.

    `lengths[b]` is row b's true prompt length (right-padded prompts); it
    drives the per-row tail extraction — full 8-token blocks below the
    row's watermark are DCT-packed, the partial remainder lands raw in the
    row's tail ring. Defaults to the full token-array length for every row
    (the lock-step case).  Each plan segment bulk-compresses its own layer
    range with its own keep (legacy scalar `keep` => uniform plan).

    Only the prompt's own blocks run through the codec; the rest of the
    max_seq store is zero-filled directly, so admission cost scales with
    the prompt, not the pool depth.
    """
    assert cfg.attn_type == "gqa"
    plan = plan_lib.as_plan(plan, keep=keep)
    b, s = tokens.shape
    lengths = kvc.as_pos_vec(s if lengths is None else lengths, b)
    logits, raw = T.prefill(params, tokens, cfg, max_seq, cache_dtype=jnp.float32)
    nb_total = max_seq // kvc.BLOCK
    nb_used = min(-(-s // kvc.BLOCK), nb_total)  # blocks covering the prompt
    segments = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        kseg = pol.kv_keep
        comp = jax.vmap(
            lambda k, v: kvc.prefill_compress(k, v, kseg, pos=lengths,
                                              backend=pol.backend,
                                              codec=pol.codec)
        )(raw["k"][start:stop, :, :nb_used * kvc.BLOCK],
          raw["v"][start:stop, :, :nb_used * kvc.BLOCK])  # vmap over layers
        if nb_used < nb_total:  # zero-fill the unwritten block range (axis 2)
            padb = lambda a: jnp.pad(
                a, ((0, 0), (0, 0), (0, nb_total - nb_used)) + ((0, 0),) * (a.ndim - 3))
            for key in comp:
                if key not in kvc.TAIL_NAMES:
                    comp[key] = padb(comp[key])
        planes = {key: comp[key].astype(dtype) if key in kvc.TAIL_NAMES
                  else comp[key] for key in comp}
        segments.append(kvc.KVSegment(
            planes, keep=kseg, start=start, stop=stop, backend=pol.backend,
            codec=pol.codec,
        ))
    return logits, kvc.CompressedKVCache(tuple(segments))


def prefill_compressed_paged(
    params: Params,
    tokens: jax.Array,      # (1|B, bucket) right-padded prompt, bucket % 8 == 0
    cfg,
    *,
    plan=None,
    keep: int = 4,
    lengths: jax.Array | None = None,
    dtype=jnp.bfloat16,
) -> tuple[jax.Array, tuple]:
    """Prefill one admission bucket into paged slot-update form.

    Unlike the dense path this never materializes (or zero-fills) a
    max_seq-sized store: the raw prefill cache is exactly the bucket, each
    plan segment bulk-compresses only the bucket's blocks, and the result
    is the per-segment update tree `paged_write_slot` scatters into the
    pool at engine-assigned page ids.  Admission cost is O(prompt), not
    O(max_seq) — the paper's "allocate the buffer the layer actually
    needs", applied to admission.
    """
    assert cfg.attn_type == "gqa"
    plan = plan_lib.as_plan(plan, keep=keep)
    b, s = tokens.shape
    assert s % kvc.BLOCK == 0, s
    lengths = kvc.as_pos_vec(s if lengths is None else lengths, b)
    logits, raw = T.prefill(params, tokens, cfg, s, cache_dtype=jnp.float32)
    update = []
    for start, stop, pol in plan.segments(cfg.n_layers):
        kseg = pol.kv_keep
        comp = jax.vmap(
            lambda k, v: kvc.prefill_compress(k, v, kseg, pos=lengths,
                                              backend=pol.backend,
                                              codec=pol.codec)
        )(raw["k"][start:stop], raw["v"][start:stop])  # vmap over layers
        comp["tail_k"] = comp["tail_k"].astype(dtype)
        comp["tail_v"] = comp["tail_v"].astype(dtype)
        update.append(comp)
    return logits, tuple(update)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    max_new_tokens: int = 64
    kv_compress: bool = False
    kv_keep: int = 4             # legacy scalar shim => CompressionPlan.uniform
    plan: Any = None             # CompressionPlan | spec string | int keep
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stops early
    kv_block: int = 1024
    codec_backend: str | None = None  # None = auto (repro.codec.dispatch)
    mesh: Any = None             # jax.sharding.Mesh: shard the serve loop on
                                 # (data, model); None = single-device path
    # Paged pool (the paper's dynamic feature-map buffer allocation): set
    # either knob to replace the dense per-slot store with a shared page
    # pool + block tables. `pool_pages` sizes the pool directly in 8-token
    # block groups; `page_budget_mb` solves the page count from a byte
    # budget using the plan's per-layer accounting (pool_pages wins when
    # both are set). Requires kv_compress on a GQA family with the
    # continuous scheduler.
    pool_pages: int | None = None
    page_budget_mb: float | None = None
    # Serving pipeline (continuous scheduler only). `prefill_buckets` fixes
    # the AOT prompt-length ladder admission rounds up to (None = automatic
    # powers-of-two multiples of the 8-token block capped at max_seq); a
    # prompt that fits no bucket raises instead of silently compiling under
    # traffic. `aot_warmup` compiles the whole serving surface (every
    # rows x bucket admission shape, the fused decode step, slot splice /
    # reset / fix) at Engine construction; the cost lands in
    # stats["warmup_s"], never in prefill/decode time. `packed_admission`
    # admits all currently-free slots in ONE bucketed prefill call;
    # `async_host` runs the decode loop one step deep (dispatch t+1 before
    # reading t's tokens) with bookkeeping on a background thread. Both
    # default on; turning them off restores the serial/synchronous loop the
    # parity tests pin against.
    prefill_buckets: Any = None
    aot_warmup: bool = False
    packed_admission: bool = True
    async_host: bool = True
    # Decode-bucket ladder (paged pool only). Each bucket owns a jitted
    # decode step whose attend covers a static `bucket // 8`-entry slice of
    # the block table; the engine picks the smallest bucket covering the
    # deepest live slot's flushed context each step, so decode cost tracks
    # OCCUPIED context instead of pool capacity. None = automatic
    # powers-of-two ladder (pipeline.auto_buckets); False/"off" = single
    # full-capacity bucket (the pre-ladder behaviour); an explicit tuple
    # narrows it. `decode_tile_pages` is the paged kernel's G: pages
    # gathered (and decompressed/scored as one (G*8, hd) tile) per grid
    # step — 8 fills the MXU's 128-lane contraction at hd>=...; shrunk to a
    # divisor of the bucket's block count per jit.
    decode_buckets: Any = None
    decode_tile_pages: int = 8
    # Tiered page pool (requires the paged pool): either knob sizes a host
    # RAM backing store (serve/tiering.py::TierManager) that cold pages
    # spill to when the device free list runs low — the paper's off-chip
    # DRAM tier behind the on-chip buffer, with compressed pages keeping
    # the transfers cheap. `tier_watermarks=(low, high)` are free-page
    # FRACTIONS of the device pool: queued demand with free pages below
    # `low` parks cold slots (latest-admitted victims, exclusively-owned
    # flushed pages spilled, shared pages retained) until `high` is free
    # again; a blocked admission parks on demand regardless of the
    # watermark. `prefix_sharing` turns on copy-on-write prompt-prefix
    # sharing: identical prompt prefixes (chained content hash, verified
    # bitwise on device before trust) map the same physical pages across
    # slots, and admission reserves only the unshared suffix.
    host_pool_pages: int | None = None
    host_pool_mb: float | None = None
    tier_watermarks: Any = (0.25, 0.5)
    prefix_sharing: bool = False

    def resolved_plan(self) -> plan_lib.CompressionPlan:
        """The per-layer plan (scalar kv_keep is a uniform-plan shim)."""
        return plan_lib.as_plan(self.plan, keep=self.kv_keep,
                                backend=self.codec_backend)

    @property
    def paged(self) -> bool:
        return self.pool_pages is not None or self.page_budget_mb is not None

    def resolved_pool_pages(self, cfg) -> int:
        """Page count of the pool: explicit, or solved from the byte budget
        with the plan's per-layer page size (a page spans every layer, so
        its size is the summed per-layer block-group bytes)."""
        if self.pool_pages is not None:
            return int(self.pool_pages)
        assert self.page_budget_mb is not None
        page_b = self.resolved_plan().page_bytes(cfg)
        pages = int(self.page_budget_mb * 1e6 // page_b)
        if pages < 1:
            raise ValueError(
                f"page_budget_mb={self.page_budget_mb} holds no page "
                f"(one page = {page_b} B across {cfg.n_layers} layers)")
        return pages

    @property
    def tiered(self) -> bool:
        return (self.host_pool_pages is not None
                or self.host_pool_mb is not None)

    def resolved_host_pages(self, cfg) -> int:
        """Host-tier page count: explicit, or solved from the MB budget with
        the same per-page byte size as the device pool (host pages mirror
        the packed/scale geometry exactly — tails are never paged)."""
        if self.host_pool_pages is not None:
            return int(self.host_pool_pages)
        assert self.host_pool_mb is not None
        page_b = self.resolved_plan().page_bytes(cfg)
        pages = int(self.host_pool_mb * 1e6 // page_b)
        if pages < 1:
            raise ValueError(
                f"host_pool_mb={self.host_pool_mb} holds no page "
                f"(one page = {page_b} B across {cfg.n_layers} layers)")
        return pages


def make_steps(api: ModelAPI, sc: ServeConfig):
    """(prefill_fn, decode_fn, cache_init, vec_pos). jit left to the caller.

    prefill_fn(params, tokens, lengths=None) -> (logits, cache)
    decode_fn(params, token, cache, pos)     -> (logits, cache)

    vec_pos=True marks families whose decode accepts a per-slot (B,)
    position vector — the requirement for continuous batching. Recurrent
    families (state caches, scalar step index) report False and are served
    wave-at-a-time. The classification lives on ArchConfig.vec_pos_decode
    (shared with ModelAPI.input_specs).
    """
    cfg = api.cfg
    use_comp = sc.kv_compress and cfg.attn_type == "gqa" and \
        cfg.resolved_head_dim % 8 == 0 and cfg.vec_pos_decode

    if sc.paged and not use_comp:
        raise ValueError(
            "paged KV pool needs kv_compress=True on a GQA family with "
            f"per-slot positions (arch {cfg.name}: attn_type={cfg.attn_type}, "
            f"vec_pos_decode={cfg.vec_pos_decode})")

    if use_comp and sc.paged:
        plan = sc.resolved_plan()
        n_pages = sc.resolved_pool_pages(cfg)

        def prefill_fn(params, tokens, lengths=None):
            return prefill_compressed_paged(params, tokens, cfg, plan=plan,
                                            lengths=lengths)

        def decode_fn(params, token, cache, pos, flush_page,
                      attend_blocks=None):
            return decode_step_compressed(params, token, cache, pos, cfg,
                                          kv_block=sc.kv_block,
                                          codec_backend=sc.codec_backend,
                                          flush_page=flush_page,
                                          attend_blocks=attend_blocks,
                                          pages_per_tile=sc.decode_tile_pages)

        cache_init = lambda b: kvc.init_paged_cache(cfg, b, sc.max_seq,
                                                    n_pages, plan=plan)
        return prefill_fn, decode_fn, cache_init, True

    if use_comp:
        plan = sc.resolved_plan()

        def prefill_fn(params, tokens, lengths=None):
            return prefill_compressed(params, tokens, cfg, sc.max_seq,
                                      plan=plan, lengths=lengths)

        def decode_fn(params, token, cache, pos):
            return decode_step_compressed(params, token, cache, pos, cfg,
                                          kv_block=sc.kv_block,
                                          codec_backend=sc.codec_backend)

        cache_init = lambda b: kvc.init_compressed_cache(cfg, b, sc.max_seq,
                                                         plan=plan)
        return prefill_fn, decode_fn, cache_init, True

    if cfg.vec_pos_decode:
        def prefill_fn(params, tokens, lengths=None):
            return T.prefill(params, tokens, cfg, sc.max_seq)

        def decode_fn(params, token, cache, pos):
            return T.decode_step(params, token, cache, pos, cfg, kv_block=sc.kv_block)

        cache_init = lambda b: api.init_cache(b, sc.max_seq)
        return prefill_fn, decode_fn, cache_init, True

    # recurrent families: prefill = teacher-forced decode of the prompt
    def prefill_fn(params, tokens, lengths=None):
        b, s = tokens.shape
        # cache activations must match the params' compute dtype
        cache = api.init_cache(b, sc.max_seq, dtype=params["embed"].dtype)

        def body(carry, t):
            cache = carry
            logits, cache = api.decode_step(params, tokens[:, t], cache, t)
            return cache, logits

        cache, logits_seq = jax.lax.scan(body, cache, jnp.arange(s))
        return jnp.moveaxis(logits_seq, 0, 1), cache  # (B, S, V)

    def decode_fn(params, token, cache, pos):
        return api.decode_step(params, token, cache, pos)

    cache_init = lambda b: api.init_cache(b, sc.max_seq)
    return prefill_fn, decode_fn, cache_init, False


def make_fused_steps(prefill_fn, decode_fn, sc: ServeConfig, *, paged: bool):
    """Fuse sampling into the jitted steps so only (B,) int32 tokens ever
    leave the device.

    admit_fn(params, tokens, lengths[, rng]) -> (first_tokens, slot_cache)
        packed admission: R right-padded prompts in one bucketed prefill;
        each row's first output token is sampled from its own last prompt
        position (lengths[r]-1) on device.
    step_fn(params, token, cache, pos[, flush_page][, rng])
        -> (next_token, pos+1, cache)
        one decode step with sampling fused; token/pos stay device-resident
        between steps — the per-token logits transfer and host argmax of
        the old loop are gone.

    Greedy (temperature<=0) takes no rng argument so its signature is
    stable for AOT warmup; temperature sampling threads a per-call PRNG key
    (host-split, so the stream is deterministic per step index).
    """
    greedy = sc.temperature <= 0.0

    def pick(logits, rng):
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / sc.temperature, axis=-1).astype(jnp.int32)

    def admit_core(params, tokens, lengths, rng):
        logits, slot_cache = prefill_fn(params, tokens, lengths)
        rows = jnp.arange(tokens.shape[0])
        return pick(logits[rows, lengths - 1], rng), slot_cache

    if greedy:
        def admit_fn(params, tokens, lengths):
            return admit_core(params, tokens, lengths, None)

        if paged:
            def step_fn(params, token, cache, pos, flush_page,
                        attend_blocks=None):
                logits, cache = decode_fn(params, token, cache, pos, flush_page,
                                          attend_blocks=attend_blocks)
                return pick(logits, None), pos + 1, cache
        else:
            def step_fn(params, token, cache, pos):
                logits, cache = decode_fn(params, token, cache, pos)
                return pick(logits, None), pos + 1, cache
    else:
        def admit_fn(params, tokens, lengths, rng):
            return admit_core(params, tokens, lengths, rng)

        if paged:
            def step_fn(params, token, cache, pos, flush_page, rng,
                        attend_blocks=None):
                logits, cache = decode_fn(params, token, cache, pos, flush_page,
                                          attend_blocks=attend_blocks)
                return pick(logits, rng), pos + 1, cache
        else:
            def step_fn(params, token, cache, pos, rng):
                logits, cache = decode_fn(params, token, cache, pos)
                return pick(logits, rng), pos + 1, cache

    return admit_fn, step_fn


# ---------------------------------------------------------------------------
# Mesh placement: explicit NamedShardings for every serve step
# ---------------------------------------------------------------------------

def serve_shardings(api: ModelAPI, params: Params, sc: ServeConfig,
                    batch: int, cache_init) -> dict[str, Any]:
    """Explicit NamedShardings for the serve step functions on `sc.mesh`.

    Placement mirrors the train-path rules: params via `param_specs` with
    fsdp=False (TP on `model`, replicated across `data` — serving reads
    weights every step, FSDP re-gathers would dominate decode), the KV pool
    via `cache_specs` (batch slots on `data`, kv heads on `model`, every
    `KVSegment` leaf included), and (B,) token/position vectors on `data`.
    Single-request admission tensors (batch 1) replicate — `fit_spec` drops
    non-dividing axes — and splice into the sharded pool through the
    slot-write scatter, so admitting one request never reshards the pool.
    """
    mesh = sc.mesh
    cfg = api.cfg
    axes = tuple(mesh.axis_names)
    ns = lambda spec: NamedSharding(mesh, spec)
    pool_shapes = jax.eval_shape(lambda: cache_init(batch))
    slot_shapes = jax.eval_shape(lambda: cache_init(1))
    return {
        "params": sh.param_shardings(params, mesh, fsdp=False),
        "rep": ns(P()),
        # (B,) per-slot token/position vectors ride the slot-pool data axes
        # — including the fused step's sampled-token and pos+1 OUTPUTS, the
        # only tensors the async loop ever reads back
        "vec": sh.step_vec_sharding(mesh, batch),
        "pool": sh.cache_shardings(pool_shapes, cfg, mesh),
        "slot": sh.cache_shardings(slot_shapes, cfg, mesh),
        "tokens": ns(sh.data_batch_spec(axes, 2, dim0=batch, mesh=mesh)),
        "logits_decode": ns(sh.data_batch_spec(axes, 2, dim0=batch, mesh=mesh)),
        "logits_prefill": ns(sh.data_batch_spec(axes, 3, dim0=batch, mesh=mesh)),
        "logits_admit": ns(sh.data_batch_spec(axes, 3, dim0=1, mesh=mesh)),
    }


# ---------------------------------------------------------------------------
# Slot lifecycle helpers (jit-able; work on any cache pytree, batch axis 1)
# ---------------------------------------------------------------------------

def cache_write_slot(cache, slot_cache, slot: jax.Array):
    """Copy a single-request (batch=1) cache into slot `slot` of the pool."""
    return jax.tree.map(
        lambda c, s: jax.lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), slot, axis=1),
        cache, slot_cache,
    )


def cache_write_rows(cache, rows_cache, slots: jax.Array):
    """Scatter an R-row packed-admission cache into slots `slots` of the
    pool (any dense cache pytree, batch axis 1).  Rows the admission group
    padded to a warmed row count carry out-of-range slot ids (>= B) and are
    dropped — a padding row can land nowhere."""
    return jax.tree.map(
        lambda c, s: c.at[:, slots].set(s.astype(c.dtype), mode="drop"),
        cache, rows_cache,
    )


def token_fix(token, pos, idx, tok_vals, pos_vals):
    """Scatter admission/retirement corrections into the device-resident
    (B,) token/pos state between decode steps.  `idx` is padded to a fixed
    (B,) with out-of-range entries (dropped) so the fix compiles once."""
    return (token.at[idx].set(tok_vals, mode="drop"),
            pos.at[idx].set(pos_vals, mode="drop"))


def cache_reset_slot(cache, slot: jax.Array):
    """Zero one slot's planes on retirement (any cache pytree)."""
    return kvc.cache_reset_slot(cache, slot)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _ParkedSlot:
    """Host-side record of an evicted (parked) live slot.

    `blocks[j]` carries block j's tier bit for every FLUSHED block:
    ("host", host_page_id) for exclusively-owned pages spilled to the
    TierManager, ("device", page_id) for shared pages that stayed
    device-resident (their refcount includes this record's reference).
    `token`/`pos` are the saved device row state `_apply_fix` replays at
    resume; `tails` (the raw 8-token remainder, per segment) is filled in
    by the BackgroundWorker alongside the host copies."""
    req: Request
    token: int
    pos: int
    horizon_blocks: int           # worst-case pages to re-reserve at resume
    shared: int                   # _slot_shared at park time
    keys: list                    # _slot_keys at park time
    blocks: list                  # per flushed block: (tier, id)
    tails: Any = None


class Engine:
    """Continuous-batching request server over a shared KV pool.

    Slots are independent: each live request has its own position, so a
    retired slot is re-admitted immediately from the queue while its
    neighbours keep decoding — no request waits for the wave's slowest.
    Admission packs every free slot's request into ONE prefill call at a
    fixed ladder bucket (prompts rounded up to AOT-compiled prompt-length
    buckets — `pipeline.PrefillLadder`; `aot_warmup=True` compiles the
    whole ladder at construction so nothing compiles under traffic) and
    splices each row into its slot; live slots are never re-prefilled.

    Sampling is fused into the jitted prefill/decode steps, so only the
    `(B,)` sampled-token vector ever crosses to the host; `token`/`pos`
    stay device-resident between steps. With `async_host=True` the loop
    runs one step deep — step t+1 is dispatched before step t's tokens are
    read — and bookkeeping (token appends, latency, page returns) drains
    on a background thread. Greedy outputs are bitwise the synchronous
    serial loop's (tests/test_serve_pipeline.py).

    Sampling order is explicit: the first output token is sampled from the
    prefill logits at the prompt's last position; a decode step only runs
    while some slot still needs tokens (a request whose max_new is 1
    finishes at admission without a decode step). `stats` splits wall time
    into warmup_s / prefill_s / decode_s / host_s; `latency_stats()`
    reports p50/p99 TTFT and inter-token latency.

    `scheduler="static"` restores wave-at-a-time lock-step batching
    (right-aligned prompts, one scalar position) — the baseline the
    throughput benchmark compares against. Families without per-slot
    position support (recurrent state caches) always use it.
    """

    def __init__(self, api: ModelAPI, params: Params, sc: ServeConfig, batch: int,
                 seed: int = 0, scheduler: str = "continuous"):
        assert scheduler in ("continuous", "static"), scheduler
        self.api = api
        self.sc = sc
        self.batch = batch
        self.rng = jax.random.PRNGKey(seed)
        prefill_fn, decode_fn, cache_init, vec_pos = make_steps(api, sc)
        self.vec_pos = vec_pos
        self.scheduler = scheduler if vec_pos else "static"
        self.paged = sc.paged
        if self.paged:
            if self.scheduler != "continuous":
                raise ValueError("paged KV pool requires the continuous "
                                 "scheduler (pages follow per-slot lifetimes)")
            # host-side page allocator: the free list IS the allocation
            # policy — the device only ever sees page ids it was handed
            self._n_pages = sc.resolved_pool_pages(api.cfg)
            self._free_pages = list(range(self._n_pages))
            self._slot_pages: list[list[int]] = [[] for _ in range(batch)]
            # copy-on-write sharing makes a free-list entry a refcount-zero
            # page rather than a never-referenced one; every release goes
            # through _release_page_list so a page frees exactly once, when
            # its LAST reference drops
            self._page_refs = np.zeros(self._n_pages, np.int64)
            self._slot_shared = [0] * batch   # leading shared blocks per slot
            self._slot_keys: list[list[bytes]] = [[] for _ in range(batch)]
            self._slot_seq = [0] * batch      # admission order (victim pick)
            self._admit_seq = 0
        self._parked: dict[int, _ParkedSlot] = {}
        self._park_order: list[int] = []
        self._tier = None
        self._prefix = None
        self.paranoid_pool_checks = False
        if (sc.tiered or sc.prefix_sharing) and not self.paged:
            raise ValueError("host_pool_pages/host_pool_mb/prefix_sharing "
                             "require the paged KV pool (set pool_pages or "
                             "page_budget_mb)")
        if sc.tiered:
            self._tier = tiering.TierManager(
                jax.eval_shape(lambda: cache_init(batch)),
                sc.resolved_host_pages(api.cfg))
            lo, hi = sc.tier_watermarks
            assert 0.0 <= float(lo) <= float(hi) <= 1.0, sc.tier_watermarks
            self._wm_low = int(float(lo) * self._n_pages)
            self._wm_high = max(int(float(hi) * self._n_pages), self._wm_low)
        if sc.prefix_sharing:
            self._prefix = tiering.PrefixIndex()
        self._cache_init_raw = cache_init  # un-jitted: pool accounting
        self.trace_counts = pl.TraceCounts()
        tc = self.trace_counts
        if self.scheduler == "continuous":
            # fused-sampling steps: only (B,) int32 tokens cross to the host
            self.ladder = pl.PrefillLadder.build(sc.max_seq, sc.prefill_buckets)
            admit_fn, step_fn = make_fused_steps(prefill_fn, decode_fn, sc,
                                                 paged=self.paged)
            admit_fn = pl.counting("prefill", tc, admit_fn)
            step_fn = pl.counting("decode", tc, step_fn)
            if self.paged:
                self.decode_ladder = pl.DecodeLadder.build(sc.max_seq,
                                                           sc.decode_buckets)
                # one partial per bucket: each binds its static table-slice
                # width, so each is a distinct jit (and a distinct "decode"
                # trace — the warmed count is len(buckets))
                bucket_fns = {
                    t: functools.partial(step_fn, attend_blocks=t // kvc.BLOCK)
                    for t in self.decode_ladder.buckets}
            write_fn = pl.counting(
                "write", tc,
                kvc.paged_write_rows if self.paged else cache_write_rows)
            reset_fn = pl.counting(
                "reset", tc,
                kvc.paged_reset_slot if self.paged else cache_reset_slot)
            fix_fn = pl.counting("fix", tc, token_fix)
            if sc.mesh is None:
                self._admit_step = jax.jit(admit_fn)
                if self.paged:
                    self._decode_fns = {t: jax.jit(fn)
                                        for t, fn in bucket_fns.items()}
                    self._decode = self._decode_fns[
                        self.decode_ladder.buckets[-1]]
                else:
                    self._decode = jax.jit(step_fn)
                self._cache_init = cache_init
                self._write = jax.jit(write_fn)
                self._reset = jax.jit(reset_fn)
                self._fix = jax.jit(fix_fn)
                if self._tier is not None:
                    self._spill = jax.jit(
                        pl.counting("spill", tc, kvc.paged_gather_slot))
                    self._restore = jax.jit(
                        pl.counting("restore", tc, kvc.paged_write_slot))
                if self._prefix is not None:
                    self._match = jax.jit(
                        pl.counting("match", tc, kvc.paged_rows_match))
            else:
                shd = serve_shardings(api, params, sc, batch, cache_init)
                # place params once; the decode jit pins the same shardings,
                # so no per-call retransfer
                params = jax.device_put(params, shd["params"])
                dec_in = [shd["params"], shd["vec"], shd["pool"], shd["vec"]]
                if self.paged:
                    dec_in.append(shd["vec"])
                if sc.temperature > 0.0:
                    dec_in.append(shd["rep"])
                dec_out = (shd["vec"], shd["vec"], shd["pool"])
                if self.paged:
                    # every bucket shares the full-capacity step's shardings:
                    # inputs are shape-identical across buckets (the table
                    # slice is internal and static), so the jit cache keys
                    # only on the bound slice width
                    self._decode_fns = {
                        t: jax.jit(fn, in_shardings=tuple(dec_in),
                                   out_shardings=dec_out)
                        for t, fn in bucket_fns.items()}
                    self._decode = self._decode_fns[
                        self.decode_ladder.buckets[-1]]
                else:
                    self._decode = jax.jit(
                        step_fn, in_shardings=tuple(dec_in),
                        out_shardings=dec_out,
                    )
                # admission tensors are bucket-shaped (rows x bucket varies
                # across the warmed ladder), so the admit step rides
                # placement propagation off the committed params; the
                # splice/reset/fix jits pin the pool and (B,) state
                self._admit_step = jax.jit(admit_fn)
                pool_init = jax.jit(
                    pl.counting("cache_init", tc, lambda: cache_init(batch)),
                    out_shardings=shd["pool"])
                self._cache_init = lambda b: pool_init()
                self._write = jax.jit(write_fn, out_shardings=shd["pool"])
                self._reset = jax.jit(reset_fn, out_shardings=shd["pool"])
                if self._tier is not None:
                    # host pages live OUTSIDE the mesh: the spill gather
                    # lands replicated (one host copy reads it whole), and
                    # the restore takes the replicated host tree back in
                    # with the pool's NamedSharding pinned on the output
                    upd_shapes = jax.eval_shape(
                        kvc.paged_gather_slot,
                        jax.eval_shape(lambda: cache_init(batch)),
                        jax.ShapeDtypeStruct((), jnp.int32),
                        jax.ShapeDtypeStruct((1,), jnp.int32))
                    rep_upd = sh.host_transfer_shardings(upd_shapes, sc.mesh)
                    self._spill = jax.jit(
                        pl.counting("spill", tc, kvc.paged_gather_slot),
                        in_shardings=(shd["pool"], shd["rep"], shd["rep"]),
                        out_shardings=rep_upd)
                    self._restore = jax.jit(
                        pl.counting("restore", tc, kvc.paged_write_slot),
                        in_shardings=(shd["pool"], rep_upd, shd["rep"],
                                      shd["rep"], shd["rep"]),
                        out_shardings=shd["pool"])
                if self._prefix is not None:
                    self._match = jax.jit(
                        pl.counting("match", tc, kvc.paged_rows_match),
                        out_shardings=shd["rep"])
                self._fix = jax.jit(
                    fix_fn,
                    in_shardings=(shd["vec"], shd["vec"], shd["rep"],
                                  shd["rep"], shd["rep"]),
                    out_shardings=(shd["vec"], shd["vec"]),
                )
        elif sc.mesh is None:
            self._prefill = jax.jit(prefill_fn)
            self._decode = jax.jit(decode_fn)
            self._cache_init = cache_init
        else:
            shd = serve_shardings(api, params, sc, batch, cache_init)
            params = jax.device_put(params, shd["params"])
            # lock-step wave: the full (B, S) prompt block is data-sharded
            # and decode runs on one scalar (replicated) position
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(shd["params"], shd["vec"], shd["pool"],
                              shd["rep"]),
                out_shardings=(shd["logits_decode"], shd["pool"]),
            )
            self._prefill = jax.jit(
                prefill_fn,
                in_shardings=(shd["params"], shd["tokens"]),
                out_shardings=(shd["logits_prefill"], shd["pool"]),
            )
            pool_init = jax.jit(lambda: cache_init(batch),
                                out_shardings=shd["pool"])
            self._cache_init = lambda b: pool_init()
        self.params = params
        self.stats = {"requests": 0, "tokens_out": 0, "steps": 0,
                      "prefill_s": 0.0, "decode_s": 0.0, "host_s": 0.0,
                      "warmup_s": 0.0,
                      "slot_steps_live": 0, "slot_steps_total": 0,
                      "peak_live_slots": 0, "admit_blocked_on_pages": 0,
                      "peak_pages_in_use": 0, "decode_bucket_tokens": 0,
                      "pages_spilled": 0, "pages_restored": 0,
                      "slots_parked": 0, "slots_resumed": 0,
                      "prefix_shared_blocks": 0, "prefix_demotions": 0}
        self._lat = {"ttft_s": [], "itl_s": []}
        self._staged = []
        self._worker = None
        self._t_gen0 = 0.0
        if sc.aot_warmup and self.scheduler == "continuous":
            ctx = mesh_lib.use_mesh(sc.mesh) if sc.mesh is not None \
                else contextlib.nullcontext()
            with ctx:
                self.stats["warmup_s"] += pl.warmup_engine(self)

    # ------------------------------------------------------------------ util
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def slot_utilization(self) -> float:
        """Fraction of decode slot-steps spent on live requests."""
        return self.stats["slot_steps_live"] / max(self.stats["slot_steps_total"], 1)

    def latency_stats(self) -> dict:
        """p50/p99 TTFT and inter-token latency (seconds) over everything
        this engine has served.  TTFT = generate() entry to the request's
        first token leaving the device (admission queueing included); ITL =
        gap between a slot's consecutive token emissions on the host clock
        (pipeline bubbles included).  Zeros when nothing was served."""
        out = {}
        for key, name in (("ttft_s", "ttft"), ("itl_s", "itl")):
            vals = self._lat[key]
            for q in (50, 99):
                out[f"{name}_p{q}_s"] = \
                    float(np.percentile(vals, q)) if vals else 0.0
        return out

    def kv_pool_stats(self) -> dict:
        """Analytic footprint of this engine's KV pool: total bytes and the
        per-device slice under `sc.mesh` (the banked-buffer accounting —
        what one device/bank actually holds). No allocation: eval_shape.

        On a paged engine the report adds the allocator's view: pool pages,
        page bytes, pages currently and peak in use, and slots-per-GB (how
        many concurrent slots one GB of pool supports at this geometry —
        the headline number the paged pool improves)."""
        shapes = jax.eval_shape(lambda: self._cache_init_raw(self.batch))
        total = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(shapes))
        mesh = self.sc.mesh
        per_device = float(total) if mesh is None else sh.per_device_bytes(
            shapes, sh.cache_specs(shapes, self.api.cfg, mesh), mesh)
        out = {"kv_pool_bytes": int(total),
               "kv_bytes_per_device": per_device,
               "slots_per_gb": self.batch / max(total / 1e9, 1e-12)}
        if "measured_kv_bytes" in self.stats:
            # recorded by _run_continuous after its queue drains: the
            # data-dependent footprint per the codec families' measured
            # per-tile accounting, vs the analytic pool above
            out["measured_kv_bytes"] = float(self.stats["measured_kv_bytes"])
        if self.paged:
            if self._worker is not None:
                # settle in-flight retirements/spills so the counts (and
                # the invariant check below) see a quiescent allocator
                self._worker.flush()
            refs = self._page_refs
            out.update(
                pool_pages=self._n_pages,
                page_bytes=self.sc.resolved_plan().page_bytes(self.api.cfg),
                pages_in_use=self._n_pages - len(self._free_pages),
                pages_device_free=len(self._free_pages),
                peak_pages_in_use=self.stats["peak_pages_in_use"],
                shared_physical_pages=int((refs > 1).sum()),
                shared_extra_refs=int((refs[refs > 1] - 1).sum()),
                prefix_shared_blocks=self.stats["prefix_shared_blocks"],
                prefix_demotions=self.stats["prefix_demotions"],
            )
            if self._tier is not None:
                out.update(
                    host_pool_pages=self._tier.host_pages,
                    host_pool_bytes=self._tier.nbytes(),
                    pages_host_in_use=self._tier.in_use,
                    pages_host_free=self._tier.free_pages,
                    pages_spilled=self.stats["pages_spilled"],
                    pages_restored=self.stats["pages_restored"],
                    slots_parked=self.stats["slots_parked"],
                    slots_resumed=self.stats["slots_resumed"],
                )
            self.check_page_invariants()
        return out

    def check_page_invariants(self) -> None:
        """Allocator conservation — the tiered pool's ledger must balance:

            device_in_use + device_free + host_resident + host_free
                == pool_pages + host_pool_pages

        refcount-weighted on the device side: every free-list page has
        refcount 0, every held page's refcount equals the number of (slot,
        block) references to it across live, staged, and parked slots, and
        every host page is either free or holds exactly one parked block.
        Pure host-list arithmetic (no device sync); runs on every
        kv_pool_stats() call and — with `paranoid_pool_checks` set — after
        every admission flush and retirement, which is how the tests catch
        the page-leak bug class the PR-5 rollback fix closed."""
        if not self.paged:
            return
        free = self._free_pages
        assert len(free) == len(set(free)), "free list has duplicates"
        held = collections.Counter()
        host_held: list[int] = []
        for pages in self._slot_pages:
            held.update(pages)
        for rec in self._parked.values():
            for tier, ref in rec.blocks:
                if tier == "host":
                    host_held.append(ref)
                else:
                    held.update([ref])
        refs = self._page_refs
        for p in free:
            assert refs[p] == 0, f"free page {p} has refcount {int(refs[p])}"
        overlap = set(free) & set(held)
        assert not overlap, f"pages both free and held: {sorted(overlap)}"
        for p, n in held.items():
            assert refs[p] == n, \
                f"page {p}: refcount {int(refs[p])} != {n} references"
        assert int((refs > 0).sum()) == len(held)
        assert int(refs.sum()) == sum(held.values())
        assert len(free) + len(held) == self._n_pages, \
            (len(free), len(held), self._n_pages)
        if self._tier is not None:
            assert len(host_held) == len(set(host_held)), \
                "host page referenced by two parked blocks"
            assert self._tier.in_use == len(host_held), \
                (self._tier.in_use, sorted(host_held))
            assert (len(held) + len(free) + len(host_held)
                    + self._tier.free_pages
                    == self._n_pages + self._tier.host_pages)

    # ------------------------------------------------------------------ API
    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve every request to completion; returns them in input order.

        The caller's list is never mutated; the Request objects are (their
        out_tokens/done fields fill in as slots retire).
        """
        queue = list(requests)
        self._t_gen0 = time.perf_counter()
        d0, p0 = self.stats["decode_s"], self.stats["prefill_s"]
        # the ambient mesh context activates the model-internal shard hints
        # (sharding.logical / attn_hint) while the jits' explicit in/out
        # NamedShardings pin the step boundaries
        ctx = mesh_lib.use_mesh(self.sc.mesh) if self.sc.mesh is not None \
            else contextlib.nullcontext()
        with ctx:
            if self.scheduler == "static":
                for w0 in range(0, len(queue), self.batch):
                    self._run_wave(queue[w0:w0 + self.batch])
            else:
                self._run_continuous(queue)
                # honest attribution: whatever this call's wall time was not
                # spent dispatching/waiting on the device is host overhead
                wall = time.perf_counter() - self._t_gen0
                self.stats["host_s"] += wall \
                    - (self.stats["decode_s"] - d0) \
                    - (self.stats["prefill_s"] - p0)
        self.stats["requests"] += len(queue)
        return queue

    # ------------------------------------------------- continuous scheduler
    def _pages_needed(self, r: Request) -> int:
        """Worst-case pages request `r` can flush over its whole lifetime.

        Positions written span [0, min(plen + max_new - 1, max_seq)); a page
        is consumed per completed 8-token block, so reserving this many at
        admission guarantees a live slot never stalls mid-decode for a page
        (slot preemption is the ROADMAP follow-on that would relax this).
        """
        horizon = min(len(r.prompt) + r.max_new - 1, self.sc.max_seq)
        return horizon // kvc.BLOCK

    def _release_page_list(self, pages) -> None:
        """Drop one reference per listed page; a page rejoins the free list
        (and leaves the prefix index) when its LAST reference drops — the
        copy-on-write half of prefix sharing. Append-in-list-order keeps
        the free-list sequence identical to the pre-refcount `extend` when
        nothing is shared, so page-id determinism is preserved. Runs on
        the serve thread or the worker; the engine's flush-before-reserve
        barrier keeps the two from interleaving with allocation."""
        for p in pages:
            n = self._page_refs[p] = self._page_refs[p] - 1
            assert n >= 0, f"page {p} over-released"
            if n == 0:
                self._free_pages.append(p)
                if self._prefix is not None:
                    self._prefix.drop_page(p)

    def _release_pages(self, slot: int) -> None:
        pages, self._slot_pages[slot] = self._slot_pages[slot], []
        self._slot_shared[slot] = 0
        self._slot_keys[slot] = []
        self._release_page_list(pages)

    def _reserve_pages(self, r: Request, slot: int) -> bool:
        """Reserve `slot`'s worst-case page horizon for `r`; False = blocked
        on free pages (admission keeps the request queued, FCFS).

        With prefix sharing on, the longest leading run of FULL prompt
        blocks whose content keys already name device-resident pages is
        mapped by reference — those pages' refcounts bump and only the
        unshared suffix draws from the free list, which is the
        admission-cost collapse for common-system-prompt traffic. The
        shared run is only a candidate here: `_flush_admissions` verifies
        it bitwise on device and demotes any mismatch to fresh pages."""
        horizon = self._pages_needed(r)
        if horizon > self._n_pages:
            raise ValueError(
                f"request {r.uid} needs {horizon} pages > pool of "
                f"{self._n_pages} (raise pool_pages/page_budget_mb"
                " or lower max_new)")
        shared: list[int] = []
        keys: list[bytes] = []
        if self._prefix is not None:
            keys = self._prefix.key_fn(np.asarray(r.prompt, np.int32))
            shared = self._prefix.lookup_run(keys)[:horizon]
        if horizon - len(shared) > len(self._free_pages):
            return False
        own = [self._free_pages.pop() for _ in range(horizon - len(shared))]
        for p in shared:
            self._page_refs[p] += 1
        for p in own:
            assert self._page_refs[p] == 0, f"free page {p} had references"
            self._page_refs[p] = 1
        self._slot_pages[slot] = shared + own
        self._slot_shared[slot] = len(shared)
        self._slot_keys[slot] = keys
        if self._prefix is not None:
            # register own FULL prompt blocks immediately so later rows of
            # the same admission group already share them (still verified
            # bitwise post-splice like any other candidate)
            for j in range(len(shared), len(r.prompt) // kvc.BLOCK):
                self._prefix.register(keys[j], self._slot_pages[slot][j])
            self.stats["prefix_shared_blocks"] += len(shared)
        used = self._n_pages - len(self._free_pages)
        self.stats["peak_pages_in_use"] = max(
            self.stats["peak_pages_in_use"], used)
        return True

    def _admit(self, r: Request, cache, slot: int):
        """Stage one request into `slot` (pages already reserved): bucket
        its prompt on the AOT ladder and queue the row for the admission
        group's single prefill call (`_flush_admissions`).  An off-ladder
        prompt raises here — admission never compiles a fresh bucket under
        traffic — and the scheduler rolls the page reservation back."""
        plen = len(r.prompt)
        self._staged.append((r, slot, plen, self.ladder.bucket_for(plen)))
        return cache

    def _flush_admissions(self, cache):
        """Run the staged admission group: ONE prefill call at the group's
        widest ladder bucket (rows padded to a warmed row count), one
        batched splice into the slots/pages, first tokens sampled on device
        at each row's own last prompt position."""
        if not self._staged:
            return cache
        staged, self._staged = self._staged, []
        t0 = time.perf_counter()
        bucket = max(b for (_, _, _, b) in staged)
        rows = self.ladder.pad_rows(len(staged), self.batch)
        tokens = np.zeros((rows, bucket), np.int32)
        lengths = np.full(rows, bucket, np.int32)
        slot_ids = np.full(rows, self.batch, np.int32)  # padding rows drop
        for j, (r, slot, plen, _) in enumerate(staged):
            tokens[j, :plen] = r.prompt
            lengths[j] = plen
            slot_ids[j] = slot
        args = [self.params, jnp.asarray(tokens), jnp.asarray(lengths)]
        if self.sc.temperature > 0.0:
            self.rng, sub = jax.random.split(self.rng)
            args.append(sub)
        first, rows_cache = self._admit_step(*args)
        if self.paged:
            # splice through the block table: each row's full prompt blocks
            # land in its slot's reserved pages; bucket padding blocks (and
            # whole padding rows) carry out-of-range ids the device scatter
            # drops. Nothing max_seq-sized is written.
            page_ids = np.full((rows, bucket // kvc.BLOCK), self._n_pages,
                               np.int32)
            table = np.zeros((rows, self.sc.max_seq // kvc.BLOCK), np.int32)
            for j, (r, slot, plen, _) in enumerate(staged):
                pb = plen // kvc.BLOCK
                pages = self._slot_pages[slot]
                # shared prefix blocks are NOT rewritten (their ids stay
                # out-of-range so the scatter drops them) — that is the
                # copy-on-write contract; the table still maps them so the
                # attend reads the shared pages
                sh_n = self._slot_shared[slot]
                page_ids[j, sh_n:pb] = pages[sh_n:pb]
                table[j, :pb] = pages[:pb]
            cache = self._write(cache, rows_cache, jnp.asarray(slot_ids),
                                jnp.asarray(page_ids), jnp.asarray(table))
            if self._prefix is not None \
                    and any(self._slot_shared[s] for (_, s, _, _) in staged):
                cache = self._verify_shared(cache, staged, rows_cache,
                                            rows, bucket, slot_ids)
        else:
            cache = self._write(cache, rows_cache, jnp.asarray(slot_ids))
        firsts = np.asarray(first)
        self.stats["prefill_s"] += time.perf_counter() - t0
        t_emit = time.perf_counter()
        fix_i, fix_t, fix_p = [], [], []
        for j, (r, slot, plen, _) in enumerate(staged):
            tok = int(firsts[j])
            self.stats["tokens_out"] += 1
            finished = tok == self.sc.eos_id or r.max_new <= 1 \
                or plen >= self.sc.max_seq
            pages = None
            if finished:  # finished at admission — no decode step
                cache = self._reset(cache, jnp.int32(slot))
                if self.paged:
                    pages, self._slot_pages[slot] = self._slot_pages[slot], []
                    self._slot_shared[slot] = 0
                    self._slot_keys[slot] = []
            else:
                self._slots[slot] = r
                self._pos[slot] = plen
                self._nout[slot] = 1
                if self.paged:
                    self._admit_seq += 1
                    self._slot_seq[slot] = self._admit_seq
                    self._last_tok[slot] = tok
                fix_i.append(slot)
                fix_t.append(tok)
                fix_p.append(plen)
            self._worker.submit(functools.partial(
                self._bk_first, r, tok, t_emit - self._t_gen0, finished,
                pages, slot, t_emit))
        if fix_i:
            self._apply_fix(fix_i, fix_t, fix_p)
        if self.paged and self.paranoid_pool_checks:
            self._worker.flush()
            self.check_page_invariants()
        return cache

    def _verify_shared(self, cache, staged, rows_cache, rows, bucket,
                       slot_ids):
        """Bitwise-verify every shared-prefix candidate block on device and
        demote mismatches (copy-on-write fallback).

        Each admitted row computed its own K/V for its whole prompt, so the
        shared pages it was mapped to must equal the row's freshly computed
        blocks exactly — `paged_rows_match` compares on device without
        pulling page planes to the host. A mismatch (hash collision, by
        construction) demotes that block and every later shared block to
        fresh pages via ONE corrective splice at the same warmed
        rows x bucket shape, so sharing can only ever be a storage win,
        never an output change — and never a new jit trace."""
        nbv = bucket // kvc.BLOCK
        ver_ids = np.full((rows, nbv), self._n_pages, np.int32)
        for j, (r, slot, plen, _) in enumerate(staged):
            sh_n = self._slot_shared[slot]
            ver_ids[j, :sh_n] = self._slot_pages[slot][:sh_n]
        ok = np.asarray(self._match(cache, rows_cache, jnp.asarray(ver_ids)))
        page2 = np.full((rows, nbv), self._n_pages, np.int32)
        table2 = np.zeros((rows, self.sc.max_seq // kvc.BLOCK), np.int32)
        dirty = False
        for j, (r, slot, plen, _) in enumerate(staged):
            sh_n = self._slot_shared[slot]
            bad = [jj for jj in range(sh_n) if not ok[j, jj]]
            if bad:
                pages = self._slot_pages[slot]
                for jj in range(bad[0], sh_n):
                    if not self._free_pages:
                        raise RuntimeError(
                            "prefix-share demotion needs a free page and "
                            "the pool is empty — raise pool_pages")
                    old = pages[jj]
                    new = self._free_pages.pop()
                    self._release_page_list([old])
                    assert self._page_refs[new] == 0, new
                    self._page_refs[new] = 1
                    pages[jj] = new
                    page2[j, jj] = new
                self._slot_shared[slot] = bad[0]
                self.stats["prefix_demotions"] += sh_n - bad[0]
                dirty = True
            pb = plen // kvc.BLOCK
            table2[j, :pb] = self._slot_pages[slot][:pb]
        if dirty:
            cache = self._write(cache, rows_cache, jnp.asarray(slot_ids),
                                jnp.asarray(page2), jnp.asarray(table2))
            used = self._n_pages - len(self._free_pages)
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], used)
        return cache

    def _bk_first(self, r, tok, ttft, finished, pages, slot, t_emit):
        """Background bookkeeping for an admitted request's first token."""
        r.out_tokens.append(tok)
        self._lat["ttft_s"].append(ttft)
        self._last_emit[slot] = t_emit
        if finished:
            r.done = True
            if pages:
                self._release_page_list(pages)

    def _bk_step(self, emitted, retired, t_emit):
        """Background bookkeeping for one processed decode step: token
        appends + inter-token latency, then retirements (done flags and
        page returns, in slot order — the free-list sequence matches the
        synchronous loop's)."""
        for r, tok, slot in emitted:
            r.out_tokens.append(tok)
            self._lat["itl_s"].append(t_emit - self._last_emit[slot])
            self._last_emit[slot] = t_emit
        for r, pages in retired:
            r.done = True
            if pages:
                self._release_page_list(pages)

    def _apply_fix(self, idx, tok_vals, pos_vals):
        """Scatter admission/retirement corrections into the device-resident
        token/pos vectors (padded to one fixed (B,) shape)."""
        b = self.batch
        ii = np.full(b, b, np.int32)
        tv = np.zeros(b, np.int32)
        pv = np.zeros(b, np.int32)
        ii[:len(idx)] = idx
        tv[:len(idx)] = tok_vals
        pv[:len(idx)] = pos_vals
        self._tok_dev, self._pos_dev = self._fix(
            self._tok_dev, self._pos_dev, jnp.asarray(ii), jnp.asarray(tv),
            jnp.asarray(pv))
        self._devpos[np.asarray(idx, np.int64)] = pos_vals

    def _admit_free_slots(self, queue, cache):
        """Fill free slots from the queue (paged pools additionally gate on
        free pages, FCFS) and flush the staged group through one packed
        prefill (`packed_admission=False` caps the group at 1 — the serial
        baseline). A tiered pool first resumes parked slots — their
        requests are older than anything still queued, so they outrank new
        admissions — then runs the watermark policy: queued demand with
        free pages under the low mark parks cold slots until the high mark
        is free again, and a still-blocked reservation parks on demand."""
        group_cap = self.batch if self.sc.packed_admission else 1
        want = self._qi < len(queue) and any(s is None for s in self._slots)
        if self.paged and (want or self._parked):
            # deterministic allocator: apply every pending retirement's page
            # return (and land every spill's host copy) before reserving,
            # so the free-list sequence (and thus every page id ever
            # issued) matches the synchronous loop
            self._worker.flush()
        resumed: tuple | list = ()
        if self._tier is not None:
            cache, resumed = self._resume_parked(cache)
            if want and len(self._free_pages) < self._wm_low:
                cache = self._evict_until(self._wm_high, cache,
                                          protect=resumed)
        for i in range(self.batch):
            if self._slots[i] is not None or i in self._parked \
                    or self._qi >= len(queue):
                continue
            r = queue[self._qi]
            if self.paged:
                ok = self._reserve_pages(r, i)
                if not ok and self._tier is not None:
                    # blocked reservation: evict cold slots on demand and
                    # retry once (never past resumed slots — re-parking a
                    # slot that just streamed back would thrash)
                    cache = self._evict_until(
                        max(self._pages_needed(r), self._wm_high), cache,
                        protect=resumed)
                    ok = self._reserve_pages(r, i)
                if not ok:
                    # blocked on pages, not slots: keep decoding; the next
                    # retirement frees pages and re-tries (FCFS, so later
                    # small requests don't starve this one)
                    self.stats["admit_blocked_on_pages"] += 1
                    break
            self._qi += 1
            try:
                cache = self._admit(r, cache, i)
            except Exception:
                if self.paged:
                    # admission failed (e.g. off-ladder prompt): no staged
                    # reservation may leak out of the pool
                    self._release_pages(i)
                    for (_, s, _, _) in self._staged:
                        self._release_pages(s)
                self._staged = []
                raise
            if len(self._staged) >= group_cap:
                cache = self._flush_admissions(cache)
        return self._flush_admissions(cache)

    # ------------------------------------------------------- tiered pool
    def _drain_pending(self, cache):
        """Retire the async pipeline: process every in-flight decode step
        and run all queued bookkeeping. Afterwards `_pos == _devpos` for
        every slot (no speculative step is outstanding) and the free list
        reflects every retirement — the quiescent state parking needs."""
        while self._pending:
            fut, plive = self._pending.popleft()
            cache = self._process(fut, plive, cache)
        self._worker.flush()
        return cache

    def _park_slot(self, v: int, cache):
        """Evict live slot `v` to the host tier. Returns (parked?, cache);
        False = the host pool can't hold its exclusive pages.

        The caller drained the pipeline, so `_pos[v]` counts every emitted
        token and the device tail holds exactly the slot's raw remainder.
        Exclusively-owned flushed pages are gathered in ONE bucketed jit
        (`paged_gather_slot`, tail rows ride along) and copied host-side on
        the BackgroundWorker — overlapped with whatever decodes next;
        shared pages (refcount > 1) stay device-resident, referenced by the
        parked record. The gather consumed the OLD cache value (XLA buffers
        are immutable), so the spilled device pages return to the free list
        immediately — a later admission can reuse them before the host copy
        lands. Unflushed reserved pages simply roll back; the slot's table
        row and tail zero out, and its batch row leaves the live set."""
        pages = self._slot_pages[v]
        nb = int(self._pos[v]) // kvc.BLOCK
        spill = [(j, pages[j]) for j in range(nb)
                 if self._page_refs[pages[j]] == 1]
        if self._tier.free_pages < len(spill):
            return False, cache
        rec = _ParkedSlot(
            req=self._slots[v], token=int(self._last_tok[v]),
            pos=int(self._pos[v]), horizon_blocks=len(pages),
            shared=self._slot_shared[v], keys=self._slot_keys[v],
            blocks=[("device", pages[j]) for j in range(nb)])
        nbkt = self.ladder.bucket_for(max(len(spill), 1) * kvc.BLOCK) \
            // kvc.BLOCK
        ids = np.full(nbkt, self._n_pages, np.int32)
        ids[:len(spill)] = [p for _, p in spill]
        upd = self._spill(cache, jnp.int32(v), jnp.asarray(ids))
        host_ids = self._tier.alloc(len(spill))
        for (j, _), hid in zip(spill, host_ids):
            rec.blocks[j] = ("host", hid)
        self._worker.submit(functools.partial(
            self._bk_spill, rec, host_ids, upd))
        cache = self._reset(cache, jnp.int32(v))
        for _, p in spill:
            self._page_refs[p] = 0
            self._free_pages.append(p)
            if self._prefix is not None:
                self._prefix.drop_page(p)
        future = pages[nb:]
        self._slot_pages[v] = []
        self._slot_shared[v] = 0
        self._slot_keys[v] = []
        self._release_page_list(future)
        self._parked[v] = rec
        self._park_order.append(v)
        self.stats["pages_spilled"] += len(spill)
        self.stats["slots_parked"] += 1
        return True, cache

    def _bk_spill(self, rec, host_ids, upd):
        """Worker half of a park: pull the gathered pages+tail to the host
        and file them (the flush-before-reserve barrier orders this before
        any read_back)."""
        upd = jax.tree.map(np.asarray, upd)
        self._tier.stage_out(host_ids, upd)
        rec.tails = [{k: seg[k] for k in tiering.TAIL_KEYS} for seg in upd]

    def _resume_parked(self, cache):
        """Stream parked slots back in park order (FIFO — their requests
        are the oldest in the system). Each resume re-reserves the slot's
        worst-case horizon, splices host pages + the saved tail back in ONE
        bucketed `paged_write_slot`, rebuilds the table row, and replays
        token/pos via `_apply_fix`, so the next dispatch continues the
        request bitwise where it parked. Caller flushed the worker, so
        every staged-out byte is already in the host store."""
        resumed = []
        while self._park_order:
            v = self._park_order[0]
            rec = self._parked[v]
            n_host = sum(1 for tier, _ in rec.blocks if tier == "host")
            need = n_host + (rec.horizon_blocks - len(rec.blocks))
            if need > len(self._free_pages):
                break  # strict FIFO: later parked slots wait their turn
            nb = len(rec.blocks)
            nbkt = self.ladder.bucket_for(max(nb, 1) * kvc.BLOCK) // kvc.BLOCK
            page_ids = np.full(nbkt, self._n_pages, np.int32)
            entries, host_ids, slot_pages = [], [], []
            for j, (tier, ref) in enumerate(rec.blocks):
                if tier == "host":
                    p = self._free_pages.pop()
                    assert self._page_refs[p] == 0, p
                    self._page_refs[p] = 1
                    page_ids[j] = p
                    entries.append((j, ref))
                    host_ids.append(ref)
                    slot_pages.append(p)
                else:  # stayed device-resident (shared); ref carried over
                    slot_pages.append(ref)
            for _ in range(rec.horizon_blocks - nb):
                p = self._free_pages.pop()
                self._page_refs[p] = 1
                slot_pages.append(p)
            upd = self._tier.read_back(entries, nbkt)
            upd = [dict(seg, **tails)
                   for seg, tails in zip(upd, rec.tails)]
            table_row = np.zeros(self.sc.max_seq // kvc.BLOCK, np.int32)
            table_row[:nb] = slot_pages[:nb]
            cache = self._restore(cache, upd, jnp.int32(v),
                                  jnp.asarray(page_ids),
                                  jnp.asarray(table_row))
            self._tier.release(host_ids)
            self._slot_pages[v] = slot_pages
            self._slot_shared[v] = rec.shared
            self._slot_keys[v] = rec.keys
            if self._prefix is not None:
                for j, key in enumerate(rec.keys[:nb]):
                    self._prefix.register(key, slot_pages[j])
            self._apply_fix([v], [rec.token], [rec.pos])
            del self._parked[v]
            self._park_order.pop(0)
            resumed.append(v)
            self.stats["pages_restored"] += n_host
            self.stats["slots_resumed"] += 1
            used = self._n_pages - len(self._free_pages)
            self.stats["peak_pages_in_use"] = max(
                self.stats["peak_pages_in_use"], used)
        return cache, resumed

    def _evict_until(self, target_free: int, cache, protect=()):
        """Watermark eviction: park victims until `target_free` device
        pages are free, victims run out, or the host pool fills. Victims
        are live, unparked, unprotected slots, LATEST admission first —
        the oldest requests are closest to retiring on their own, so the
        newest slot's pages are the coldest bet. Each park drains the
        one-step-deep pipeline first (the spill gather must see a
        quiescent row)."""
        tried = set(protect)
        while len(self._free_pages) < target_free:
            victims = [i for i in range(self.batch)
                       if self._slots[i] is not None
                       and i not in self._parked and i not in tried]
            if not victims:
                break
            v = max(victims, key=lambda i: self._slot_seq[i])
            tried.add(v)
            cache = self._drain_pending(cache)
            if self._slots[v] is None:
                continue  # retired while draining — its pages came back free
            ok, cache = self._park_slot(v, cache)
            if not ok:
                break  # host pool exhausted — stop evicting
        return cache

    def _dispatch(self, cache, live):
        """Issue one fused decode step; token/pos stay on device."""
        t0 = time.perf_counter()
        args = [self.params, self._tok_dev, cache, self._pos_dev]
        decode = self._decode
        if self.paged:
            # decode-bucket ladder: the attend only reads table entries
            # below a row's flushed watermark (pos//8*8 — the page flushed
            # THIS step is still the raw tail), so the deepest live slot's
            # watermark picks the smallest warmed bucket that covers every
            # row. Retired slots' device positions reset to 0 before the
            # next dispatch, so they never hold the bucket high.
            need = max(((int(self._devpos[i]) // kvc.BLOCK) * kvc.BLOCK
                        for i in live), default=0)
            bucket = self.decode_ladder.bucket_for(need)
            decode = self._decode_fns[bucket]
            self.stats["decode_bucket_tokens"] += bucket
            # hand each flushing row its reserved page; every other row gets
            # an out-of-range id the device scatter drops. `_devpos` mirrors
            # the DEVICE position (which advances on speculative steps the
            # host hasn't processed yet); the length guard drops the flush
            # of a row whose retirement is already in flight.
            fp = np.full(self.batch, self._n_pages, np.int32)
            for i in live:
                p = int(self._devpos[i])
                blk = p // kvc.BLOCK
                if p % kvc.BLOCK == kvc.BLOCK - 1 \
                        and blk < len(self._slot_pages[i]):
                    page = self._slot_pages[i][blk]
                    if self._prefix is not None:
                        # copy-on-write guarantee: decode only ever flushes
                        # PAST the shared prefix, into a page this slot
                        # owns exclusively — a write to a shared page is
                        # structurally impossible, asserted here
                        assert blk >= self._slot_shared[i] \
                            and self._page_refs[page] == 1, \
                            (i, blk, page, int(self._page_refs[page]))
                    fp[i] = page
            args.append(jnp.asarray(fp))
        if self.sc.temperature > 0.0:
            self.rng, sub = jax.random.split(self.rng)
            args.append(sub)
        tok, pos1, cache = decode(*args)
        self._tok_dev, self._pos_dev = tok, pos1
        self._devpos += 1
        self.stats["steps"] += 1
        self.stats["slot_steps_total"] += self.batch
        self.stats["slot_steps_live"] += len(live)
        self.stats["decode_s"] += time.perf_counter() - t0
        return cache, tok

    def _process(self, fut, plive, cache):
        """Read one completed step's tokens and apply its bookkeeping.

        `plive` is the (slot, request) snapshot at dispatch time; a slot
        retired (or re-admitted) while the step was in flight is skipped —
        the speculative step only ever touched that slot's own planes, all
        overwritten at the next admission."""
        t0 = time.perf_counter()
        toks = np.asarray(fut)  # the only device->host sync of the loop
        self.stats["decode_s"] += time.perf_counter() - t0
        t_emit = time.perf_counter()
        emitted, retired, fix_i = [], [], []
        for i, r in plive:
            if self._slots[i] is not r:
                continue
            tok = int(toks[i])
            self._nout[i] += 1
            self._pos[i] += 1
            self.stats["tokens_out"] += 1
            emitted.append((r, tok, i))
            if self.paged:
                self._last_tok[i] = tok  # park/resume replays this
            if tok == self.sc.eos_id or self._nout[i] >= r.max_new \
                    or self._pos[i] >= self.sc.max_seq:
                self._slots[i] = None  # retire; slot re-admits next round
                self._pos[i] = 0
                self._nout[i] = 0
                cache = self._reset(cache, jnp.int32(i))
                pages = None
                if self.paged:
                    pages, self._slot_pages[i] = self._slot_pages[i], []
                    self._slot_shared[i] = 0
                    self._slot_keys[i] = []
                retired.append((r, pages))
                fix_i.append(i)
        if emitted:
            self._worker.submit(functools.partial(
                self._bk_step, emitted, retired, t_emit))
        if fix_i:
            self._apply_fix(fix_i, [0] * len(fix_i), [0] * len(fix_i))
            if self.paged and self.paranoid_pool_checks:
                self._worker.flush()
                self.check_page_invariants()
        return cache

    def _run_continuous(self, queue: list[Request]) -> None:
        b = self.batch
        self._slots: list[Request | None] = [None] * b
        self._pos = np.zeros(b, np.int64)      # logical per-slot position
        self._nout = np.zeros(b, np.int64)     # tokens emitted per slot
        self._devpos = np.zeros(b, np.int64)   # device pos mirror (see _dispatch)
        self._last_emit = np.zeros(b)
        self._last_tok = np.zeros(b, np.int64)  # last emitted token per slot
        self._tok_dev = jnp.zeros((b,), jnp.int32)
        self._pos_dev = jnp.zeros((b,), jnp.int32)
        self._staged = []
        self._qi = 0
        cache = self._cache_init(b)
        # async_host: run one step deep — dispatch step t+1 before reading
        # step t's tokens, so the device never idles on host bookkeeping.
        # Slot independence makes the speculation safe: a step dispatched
        # for a slot that retires under it only writes that slot's own
        # tail/table/pages, all reset or overwritten before anything reads
        # them, and its token is discarded in _process.
        depth = 1 if self.sc.async_host else 0
        self._pending = pending = collections.deque()
        self._worker = pl.BackgroundWorker()
        idle_spins, last_state = 0, None
        try:
            while True:
                cache = self._admit_free_slots(queue, cache)
                # parked slots keep their Request in _slots (the slot stays
                # reserved for them) but leave the live set: their batch
                # row decodes garbage that is never read, and their pages
                # are host-side until resume
                live = [(i, r) for i, r in enumerate(self._slots)
                        if r is not None and i not in self._parked]
                if not live and not pending:
                    if self._qi >= len(queue) and not self._parked:
                        break
                    # everything retired at admission (or only parked slots
                    # remain); admit/resume more. Guard the spin: a parked
                    # slot that can never resume would otherwise loop here
                    # forever.
                    state = (self._qi, len(self._parked),
                             len(self._free_pages) if self.paged else 0)
                    idle_spins = idle_spins + 1 if state == last_state else 0
                    last_state = state
                    if idle_spins > 2 * self.batch + 4:
                        raise RuntimeError(
                            "serve loop wedged: no live slots and no "
                            f"progress (parked={sorted(self._parked)}, "
                            f"qi={self._qi}/{len(queue)})")
                    continue
                idle_spins, last_state = 0, None
                if live:
                    self.stats["peak_live_slots"] = max(
                        self.stats["peak_live_slots"], len(live))
                    cache, fut = self._dispatch(cache, [i for i, _ in live])
                    pending.append((fut, live))
                if len(pending) > depth or (pending and not live):
                    fut, plive = pending.popleft()
                    cache = self._process(fut, plive, cache)
            # queue drained: record the DATA-DEPENDENT pool footprint next to
            # the analytic one (kv_pool_stats reports both) — variable-length
            # codec families (bitplane) are the reason the two differ.  Raw
            # caches (kv_compress=False) are a plain dict with nothing to
            # measure.
            if hasattr(cache, "segments"):
                self.stats["measured_kv_bytes"] = \
                    kvc.measured_cache_bytes(cache)
        finally:
            worker, self._worker = self._worker, None
            worker.close()

    # ----------------------------------------------------- static scheduler
    def _run_wave(self, wave: list[Request]) -> None:
        """Lock-step wave: right-aligned prompts, one scalar position."""
        assert len(wave) <= self.batch
        # every wave request is live from prefill until it retires
        self.stats["peak_live_slots"] = max(self.stats["peak_live_slots"],
                                            len(wave))
        slots = list(wave) + [
            Request(uid=-1, prompt=np.zeros(kvc.BLOCK, np.int32), max_new=1)
            for _ in range(self.batch - len(wave))
        ]
        plen = max(kvc.BLOCK, max(len(r.prompt) for r in slots))
        prompts = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(slots):
            prompts[i, plen - len(r.prompt):] = r.prompt  # right-align

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        self.stats["prefill_s"] += time.perf_counter() - t0

        # explicit ordering: sample from prefill -> append/check -> only then
        # decode. If every request finishes on its first token, no decode
        # step runs and no logits are sampled twice.
        token = self._sample(logits[:, -1])
        max_new = max(r.max_new for r in wave)
        done = np.zeros(self.batch, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            tok_np = np.asarray(token)
            for i, r in enumerate(slots):
                if r.uid >= 0 and not r.done:
                    tok = int(tok_np[i])
                    r.out_tokens.append(tok)
                    self.stats["tokens_out"] += 1
                    if tok == self.sc.eos_id or len(r.out_tokens) >= r.max_new:
                        r.done = True
                done[i] = r.done or r.uid < 0
            if done.all():
                break
            if plen + step >= self.sc.max_seq:
                # context exhausted: no slot can write another token — retire
                # the wave truncated (mirrors the continuous pos >= max_seq
                # guard) instead of silently dropping K/V writes
                for r in slots:
                    if r.uid >= 0:
                        r.done = True
                break
            logits_step, cache = self._decode(self.params, token, cache,
                                              jnp.int32(plen + step))
            token = self._sample(logits_step)
            self.stats["steps"] += 1
            self.stats["slot_steps_total"] += self.batch
            self.stats["slot_steps_live"] += int((~done).sum())
        self.stats["decode_s"] += time.perf_counter() - t0
