"""Host-side serving pipeline: AOT prefill/decode buckets + async bookkeeping.

Four pieces, all host machinery (nothing here traces into a jit):

  * `PrefillLadder` — the fixed set of prompt-length buckets the engine
    compiles AHEAD of traffic.  Admission rounds every prompt up to the
    smallest covering bucket, so the jit cache is warmed once at engine
    construction and no XLA compilation ever happens under traffic.  The
    auto ladder is powers-of-two multiples of the 8-token DCT block capped
    at max_seq (8, 16, 32, ..., max_seq); an explicit ladder narrows it,
    and a prompt that fits no bucket raises — never a silent compile.

  * `DecodeLadder` — the paged engine's context-length buckets.  Each
    bucket owns a jitted decode step whose attend covers a static
    `bucket // 8`-entry slice of the block table; the engine picks the
    smallest bucket covering the deepest live slot's flushed watermark at
    every dispatch, so decode-step cost scales with OCCUPIED context
    instead of pool capacity.  All buckets are warmed at construction
    exactly like the prefill ladder (zero jit traces under traffic), and
    the slice is an exact no-op on outputs: dropped table entries can only
    name blocks the watermark masks anyway.

  * `BackgroundWorker` — a daemon thread draining a backlog queue of
    bookkeeping closures (token appends, latency accounting, returning a
    retired slot's pages to the free list).  The serve loop hands finished
    host work here so the device never waits on Python bookkeeping between
    decode steps (the MaxText offline-inference idiom, adapted to the
    paged pool where retirement must also release pages).  `flush()` is
    the synchronization point: admission blocked on free pages flushes the
    backlog before deciding the pool is really exhausted.

  * `TraceCounts` / `counting` — per-callable jit-trace counters.  The
    wrapped function body increments its counter as a trace-time side
    effect, so `counts` advances exactly when XLA (re)compiles.  The
    zero-compile-under-traffic regression test snapshots the counts after
    warmup and asserts serving moves none of them.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass

BLOCK = 8  # the DCT seq-block; ladder buckets are multiples of it


# ---------------------------------------------------------------------------
# AOT prefill bucket ladder
# ---------------------------------------------------------------------------

def auto_buckets(max_seq: int) -> tuple[int, ...]:
    """Powers-of-two multiples of BLOCK capped at max_seq, max_seq included.

    max_seq=48 -> (8, 16, 32, 48); max_seq=64 -> (8, 16, 32, 64).
    """
    assert max_seq % BLOCK == 0 and max_seq >= BLOCK, max_seq
    out = []
    b = BLOCK
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


@dataclass(frozen=True)
class PrefillLadder:
    """The fixed prompt-length buckets admission rounds up to."""

    buckets: tuple[int, ...]

    @classmethod
    def build(cls, max_seq: int, buckets=None) -> "PrefillLadder":
        if buckets is None:
            return cls(auto_buckets(max_seq))
        buckets = tuple(sorted(int(b) for b in buckets))
        if not buckets:
            raise ValueError("empty prefill ladder")
        for b in buckets:
            if b % BLOCK or b < BLOCK:
                raise ValueError(f"ladder bucket {b} is not a multiple of {BLOCK}")
        if buckets[-1] > max_seq:
            raise ValueError(
                f"ladder bucket {buckets[-1]} exceeds max_seq={max_seq}")
        return cls(buckets)

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket covering `prompt_len`; raises off-ladder.

        The raise is the explicit alternative to silently jit-compiling a
        fresh prefill under traffic: the caller either re-buckets the
        workload or widens the ladder, both ahead of time.
        """
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt of {prompt_len} tokens fits no prefill bucket "
            f"{self.buckets}: off-ladder admission would compile under "
            f"traffic (widen prefill_buckets or raise max_seq)")

    def row_counts(self, batch: int) -> tuple[int, ...]:
        """Admission-batch row counts the engine pads to: powers of two up
        to `batch`, plus `batch` itself — the full warmup set."""
        out = []
        r = 1
        while r < batch:
            out.append(r)
            r *= 2
        out.append(batch)
        return tuple(out)

    def pad_rows(self, n: int, batch: int) -> int:
        """Round an admission group of n requests up to a warmed row count."""
        for r in self.row_counts(batch):
            if n <= r:
                return r
        return batch


# ---------------------------------------------------------------------------
# Decode-bucket ladder (paged pool)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DecodeLadder:
    """Context-length buckets the paged decode step is compiled at.

    A bucket of T tokens means a decode step whose attend reads only the
    first T // 8 block-table entries (a static slice — see
    core.kv_cache.table_view).  The ladder always ends at max_seq, so any
    legal flushed watermark has a covering bucket; `bucket_for` never
    raises under traffic the pool itself can hold.
    """

    buckets: tuple[int, ...]

    @classmethod
    def build(cls, max_seq: int, buckets=None) -> "DecodeLadder":
        if buckets is None:  # auto: powers-of-two x BLOCK, max_seq included
            return cls(auto_buckets(max_seq))
        if buckets is False or buckets == "off":
            return cls((max_seq,))  # single full-capacity bucket (pre-ladder)
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets:
            raise ValueError("empty decode ladder")
        for b in buckets:
            if b % BLOCK or b < BLOCK:
                raise ValueError(
                    f"decode bucket {b} is not a multiple of {BLOCK}")
        if buckets[-1] > max_seq:
            raise ValueError(
                f"decode bucket {buckets[-1]} exceeds max_seq={max_seq}")
        if buckets[-1] < max_seq:
            buckets = buckets + (max_seq,)  # must always cover a full pool
        return cls(buckets)

    def bucket_for(self, context_tokens: int) -> int:
        """Smallest bucket covering `context_tokens` of flushed context."""
        for b in self.buckets:
            if context_tokens <= b:
                return b
        raise ValueError(
            f"flushed context of {context_tokens} tokens exceeds the decode "
            f"ladder {self.buckets} — deeper than the pool itself")


# ---------------------------------------------------------------------------
# Jit-trace accounting
# ---------------------------------------------------------------------------

class TraceCounts(dict):
    """name -> number of times the named callable was traced by jit."""

    def snapshot(self) -> dict:
        return dict(self)

    def delta(self, since: dict) -> dict:
        return {k: v - since.get(k, 0) for k, v in self.items()
                if v != since.get(k, 0)}


def counting(name: str, counts: TraceCounts, fn):
    """Wrap `fn` so tracing it (and thus compiling it) bumps counts[name].

    The increment runs when the *python* body runs — under jit that is once
    per trace, never per execution — so the counter is a compile counter.
    """
    counts.setdefault(name, 0)

    def wrapped(*args, **kwargs):
        counts[name] += 1
        return fn(*args, **kwargs)

    wrapped.__name__ = f"traced_{name}"
    return wrapped


# ---------------------------------------------------------------------------
# Background bookkeeping worker
# ---------------------------------------------------------------------------

class BackgroundWorker:
    """Daemon thread running bookkeeping closures from a backlog queue.

    The serve loop submits closures (append tokens, record latency, return
    pages); the worker runs them strictly in submission order, so
    per-request token order and free-list state are deterministic.  Errors
    are captured and re-raised on the serve thread at the next `flush()` /
    `close()` — a bookkeeping bug must fail the request loop, not vanish
    in a thread."""

    def __init__(self, name: str = "serve-bookkeeping"):
        self._q: queue.Queue = queue.Queue()
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                if self._err is None:
                    item()
            except BaseException as e:  # surfaced at flush()/close()
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, fn) -> None:
        if self._err is not None:
            self._reraise()
        self._q.put(fn)

    def flush(self) -> None:
        """Block until every submitted closure has run; re-raise errors."""
        self._q.join()
        if self._err is not None:
            self._reraise()

    def close(self) -> None:
        self._q.join()
        self._q.put(None)
        self._thread.join()
        if self._err is not None:
            self._reraise()

    def _reraise(self):
        err, self._err = self._err, None
        raise err


# ---------------------------------------------------------------------------
# Engine warmup: compile the whole serving surface before traffic
# ---------------------------------------------------------------------------

def warmup_engine(engine) -> float:
    """AOT-compile every (rows x bucket) admission shape plus the decode /
    splice / reset / fix steps the continuous scheduler can issue.

    Runs real dummy calls (the only way the pinned jax version is
    guaranteed to populate the jit executable cache) against a scratch
    pool; every splice targets out-of-range slots/pages, so a warmed
    engine's first real pool is still all-zeros.  Returns wall seconds;
    the engine accounts them as `stats["warmup_s"]`, never as prefill or
    decode time.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    t0 = time.perf_counter()
    temp = engine.sc.temperature > 0.0
    rng = jax.random.PRNGKey(0)  # warmup never touches the engine's stream
    cache = engine._cache_init(engine.batch)
    zeros_b = jnp.zeros((engine.batch,), jnp.int32)
    ladder = engine.ladder
    nb_table = engine.sc.max_seq // BLOCK
    for bucket in ladder.buckets:
        for rows in ladder.row_counts(engine.batch):
            tokens = jnp.zeros((rows, bucket), jnp.int32)
            lengths = jnp.full((rows,), bucket, jnp.int32)
            args = [engine.params, tokens, lengths] + ([rng] if temp else [])
            first, slot_cache = engine._admit_step(*args)
            drop_slots = jnp.full((rows,), engine.batch, jnp.int32)
            if engine.paged:
                page_ids = jnp.full((rows, bucket // BLOCK), engine._n_pages,
                                    jnp.int32)
                table_rows = jnp.zeros((rows, nb_table), jnp.int32)
                cache = engine._write(cache, slot_cache, drop_slots,
                                      page_ids, table_rows)
            else:
                cache = engine._write(cache, slot_cache, drop_slots)
            if engine.paged and engine._prefix is not None:
                # prefix verification runs at every admission shape
                engine._match(cache, slot_cache,
                              page_ids).block_until_ready()
            first.block_until_ready()
    # decode + slot lifecycle steps.  A paged engine owns one decode jit
    # per ladder bucket (static table-slice width) — warm every one; the
    # dense engine has a single decode shape.
    step_args = [engine.params, zeros_b, cache, zeros_b]
    if engine.paged:
        step_args.append(jnp.full((engine.batch,), engine._n_pages, jnp.int32))
    if temp:
        step_args.append(rng)
    if engine.paged:
        for fn in engine._decode_fns.values():
            tok, pos1, cache = fn(*step_args)
    else:
        tok, pos1, cache = engine._decode(*step_args)
    if engine.paged and engine._tier is not None:
        # tier fault path: one spill gather + one restore splice per
        # prefill-ladder width, driven EXACTLY as the engine issues them at
        # park/resume time — numpy host trees in (host pages live outside
        # any mesh), pool-sharded cache out — so a tiered engine never
        # compiles under traffic either. All page ids are out-of-range:
        # the warmup restore writes nothing.
        for bucket in ladder.buckets:
            nbkt = bucket // BLOCK
            ids = np.full((nbkt,), engine._n_pages, np.int32)
            upd = engine._spill(cache, jnp.int32(0), jnp.asarray(ids))
            upd = jax.tree.map(np.asarray, upd)
            cache = engine._restore(
                cache, upd, jnp.int32(0), jnp.asarray(ids),
                jnp.asarray(np.zeros((nb_table,), np.int32)))
    cache = engine._reset(cache, jnp.int32(0))
    drop_idx = jnp.full((engine.batch,), engine.batch, jnp.int32)
    tok, pos1 = engine._fix(tok, pos1, drop_idx, zeros_b, zeros_b)
    tok.block_until_ready()
    del cache
    np.asarray(tok)  # drain
    return time.perf_counter() - t0
