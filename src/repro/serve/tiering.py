"""Page tiers for the paged compressed KV pool: host offload + prefix sharing.

Two host-side pieces, both pure allocator state (nothing here traces into a
jit — the device only ever sees page ids and update trees the engine hands
it, exactly like the free list that PR 5 introduced):

  * `TierManager` — the off-chip half of the paper's memory hierarchy. It
    owns a pinned numpy backing store shaped like the device pool's packed /
    scale planes but `host_pages` deep, plus its own free list. When the
    engine's watermark policy evicts (parks) a victim slot, the slot's
    fully-flushed pages are gathered off the device in ONE bucketed jit
    (`kv_cache.paged_gather_slot`), copied into host pages on the
    `BackgroundWorker` (overlapped with decode, one step deep), and the
    device pages return to the free list. The fault path is the inverse:
    a parked slot resumes by streaming its host pages back through one
    `paged_write_slot` jit BEFORE its next attend — the engine only marks a
    slot live again after the restore is dispatched, and the decode bucket
    ladder makes "which pages are attendable" exact, so the prefetch is
    provable rather than heuristic. Pages hold compressed int8 DCT blocks +
    f32 scales, so a spill moves ~6-16x fewer bytes than raw K/V — the
    EBPC argument that compressed transfers make the DRAM tier affordable.

  * `PrefixIndex` — content addressing for copy-on-write prefix sharing.
    `prefix_block_keys` chains a blake2b digest over each full 8-token
    prompt block, so key j commits to tokens[0:8*(j+1)] — exactly the
    inputs block j's K/V depends on under causal attention with absolute
    rope. Admission looks up the longest leading run of device-resident
    hits and reserves pages only for the unshared suffix; the engine then
    VERIFIES candidate pages bitwise on device (`paged_rows_match`) before
    trusting them, so a hash collision can only ever cost a demotion (copy
    into fresh pages), never alias two different prefixes. The index maps
    key <-> page both ways: a page is dropped from the index the moment it
    is freed or spilled (host pages are not shareable), and re-registered
    when a parked slot's restore brings the same bytes back.

The tier bit itself lives host-side, with the allocator: the engine's
per-slot page lists and parked-slot records know whether a logical block is
device- or host-resident, while device block tables only ever contain
device page ids (a parked slot's table row is zeroed, and rebuilt by the
restore). Keeping the bit out of the jitted tables is what lets every
existing decode/attend jit run unchanged — tiering is pure allocator
policy, like the free list before it.
"""
from __future__ import annotations

import hashlib

import numpy as np

BLOCK = 8  # tokens per page (the DCT seq-block)

# The dct family's page planes — kept as a module constant for callers/tests
# that reason about the default family. TierManager itself derives each
# segment's plane set from the segment (codec families differ: bitplane pages
# also carry bpmask/blen planes), so mixed-codec plans tier correctly.
PAGE_KEYS = ("packed_k", "scale_k", "packed_v", "scale_v")
TAIL_KEYS = ("tail_k", "tail_v")


def _segment_page_keys(seg) -> tuple[str, ...]:
    """Pageable plane names for one cache segment (everything but tails)."""
    keys = getattr(seg, "page_keys", None)
    if keys is not None:
        return tuple(keys)
    return PAGE_KEYS


# ---------------------------------------------------------------------------
# Prefix hashing
# ---------------------------------------------------------------------------

def prefix_block_keys(prompt: np.ndarray) -> list[bytes]:
    """Chained content keys for every FULL 8-token block of `prompt`.

    keys[j] is a blake2b digest over tokens[0 : 8*(j+1)] — the whole prefix
    through block j, not just block j's own tokens. Block j's K/V is a pure
    function of exactly that prefix (causal attention, absolute rope), so
    two prompts agreeing on keys[0..j] computed the same K/V for those
    blocks — up to hash collision, which the engine closes by verifying
    candidate pages bitwise on device before sharing them.

    Only full blocks get keys (a partial block lives in the raw tail ring
    and is never paged), and the result depends on nothing but the prompt
    tokens themselves — not the admission bucket, the batch row the prompt
    lands in, or any padding (pinned by a hypothesis property test).
    """
    arr = np.ascontiguousarray(np.asarray(prompt, dtype=np.int32))
    h = hashlib.blake2b(digest_size=16)
    keys = []
    for j in range(len(arr) // BLOCK):
        h.update(arr[j * BLOCK:(j + 1) * BLOCK].tobytes())
        keys.append(h.digest())
    return keys


class PrefixIndex:
    """key <-> device-page bimap behind copy-on-write prefix sharing.

    `key_fn` is injectable so tests can force collisions and prove the
    device-side bitwise verification (not the hash) is what prevents
    aliasing. Registration is first-writer-wins: once a key names a page,
    later identical prefixes share that page instead of re-registering.
    """

    def __init__(self, key_fn=prefix_block_keys):
        self.key_fn = key_fn
        self._by_key: dict[bytes, int] = {}
        self._by_page: dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def lookup_run(self, keys: list[bytes]) -> list[int]:
        """Pages for the longest LEADING run of registered keys.

        Sharing must stop at the first miss: block j's reuse is only sound
        when every block before it is shared too (the chained key encodes
        that, but the run guard keeps a later accidental hit from creating
        a hole in the slot's table).
        """
        pages = []
        for key in keys:
            page = self._by_key.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def register(self, key: bytes, page: int) -> None:
        if key in self._by_key:  # first writer wins
            return
        self._by_key[key] = page
        self._by_page[page] = key

    def drop_page(self, page: int) -> None:
        """Forget a page (freed or spilled to host) — both directions."""
        key = self._by_page.pop(page, None)
        if key is not None:
            self._by_key.pop(key, None)


# ---------------------------------------------------------------------------
# Host page pool
# ---------------------------------------------------------------------------

class TierManager:
    """Host (off-device) page pool + free list for spilled compressed pages.

    The backing store mirrors the device pool's packed/scale geometry with
    `host_pages` on the page axis: per segment
    ``packed_k/v (Lseg, HP, Hkv, hd/8, k, k) int8`` and
    ``scale_k/v (Lseg, HP, Hkv, hd/8) f32`` — plain numpy, outside any mesh
    (the parallel/sharding helpers only ever see the restored update on its
    way back in). Allocation is id-based like the engine's device free
    list; content moves in `stage_out` (worker thread) and `read_back`
    (admission path, after a `worker.flush()` barrier, so a parked slot's
    bytes are always complete before they stream back).
    """

    def __init__(self, cache_shapes, host_pages: int):
        assert host_pages >= 1, host_pages
        self.host_pages = int(host_pages)
        self._free = list(range(self.host_pages))
        self._store: list[dict[str, np.ndarray]] = []
        self._page_keys: list[tuple[str, ...]] = []
        for seg in cache_shapes.segments:
            keys = _segment_page_keys(seg)
            plane_map = getattr(seg, "planes", None)
            planes = {}
            for key in keys:
                ref = plane_map[key] if plane_map is not None \
                    else getattr(seg, key)
                shape = (ref.shape[0], self.host_pages) + tuple(ref.shape[2:])
                planes[key] = np.zeros(shape, dtype=np.dtype(ref.dtype))
            self._store.append(planes)
            self._page_keys.append(keys)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.host_pages - len(self._free)

    def nbytes(self) -> int:
        return sum(int(a.nbytes) for planes in self._store
                   for a in planes.values())

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"host page pool exhausted: need {n}, free {len(self._free)}"
                f" of {self.host_pages}")
        return [self._free.pop() for _ in range(n)]

    def release(self, host_ids: list[int]) -> None:
        self._free.extend(host_ids)

    def stage_out(self, host_ids: list[int], update) -> None:
        """Copy gathered page content into host pages (worker thread).

        `update` is the numpy-ified `paged_gather_slot` tree; entry i of
        its page axis corresponds to host_ids[i]. Runs off the serve
        thread; the engine's `worker.flush()` before any read_back is the
        completion barrier.
        """
        for planes, keys, upd in zip(self._store, self._page_keys, update):
            for key in keys:
                src = np.asarray(upd[key])  # (Lseg, 1, nbkt, ...)
                for i, hid in enumerate(host_ids):
                    planes[key][:, hid] = src[:, 0, i]

    def read_back(self, entries: list[tuple[int, int]], nbkt: int):
        """Assemble the restore update for `paged_write_slot`.

        `entries` are (position, host_id) pairs: the host page streams back
        into page-axis position `position` of an (Lseg, 1, nbkt, ...)
        update (positions past the parked slot's host blocks stay zero and
        carry out-of-range page ids, so the scatter drops them). Tails are
        the caller's (they live in the parked record, not the page pool).
        """
        out = []
        for planes, keys in zip(self._store, self._page_keys):
            upd = {}
            for key in keys:
                ref = planes[key]
                buf = np.zeros((ref.shape[0], 1, nbkt) + ref.shape[2:],
                               dtype=ref.dtype)
                for pos, hid in entries:
                    buf[:, 0, pos] = ref[:, hid]
                upd[key] = buf
            out.append(upd)
        return out
