"""Distributed train step: microbatched grad accumulation, remat/ActCompress,
optional cross-pod GradCompress, AdamW with FSDP/ZeRO-sharded state.

Two gradient-exchange modes:
  * plain (baseline): pure jit + GSPMD — the cross-pod all-reduce is whatever
    XLA schedules (f32 payload).
  * compressed: a partial-manual shard_map over the `pod` axis (data/model
    stay auto/GSPMD). Per-pod local grads are DCT-truncated to int8, exchanged
    with all_gather over `pod`, decompressed and averaged, with per-leaf error
    feedback (core/grad_comp.py). Wire bytes on the slow link drop ~12x.

Microbatching: the (B, S) global batch is reshaped to (n_micro, mb, S) and
scanned; only one microbatch's activations are live at a time, which is what
lets 340B-class configs fit 16 GB HBM (with sequence-sharded, optionally
DCT-compressed, saved residuals).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.codec import plan as plan_lib
from repro.core import grad_comp
from repro.models.api import ModelAPI
from repro.optim import adamw
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh

Params = dict[str, Any]


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"            # none | full | compressed (ActCompress)
    plan: Any = None               # ActCompress per-layer CompressionPlan
                                   # (plan object | spec string | int keep)
    compress_keep: int = 4         # legacy scalar shim => uniform plan
    codec_backend: Any = None      # legacy backend shim => plan backend
                                   # (None = auto per repro.codec.dispatch)
    codec: Any = None              # codec family override for every layer
                                   # (None = keep the plan's, default dct)
    grad_compress: bool = False    # cross-pod DCT gradient exchange
    grad_compress_keep: int = 5
    grad_reduce_dtype: Any = jnp.bfloat16  # wire dtype of per-microbatch
                                   # grad reduction (accumulation stays f32)
    optimizer: adamw.AdamWConfig = field(default_factory=adamw.AdamWConfig)
    param_dtype: Any = jnp.bfloat16
    fsdp: bool = True


def init_train_state(api: ModelAPI, tc: TrainConfig, seed: int = 0) -> dict[str, Any]:
    params = api.init(jax.random.PRNGKey(seed), dtype=tc.param_dtype)
    state = {
        "params": params,
        "opt": adamw.init_state(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.grad_compress:
        state["gc_residual"] = grad_comp.init_residual(params)
    return state


def state_specs(state: dict[str, Any], mesh: Mesh, tc: TrainConfig):
    """PartitionSpecs for the full train state (opt state mirrors params)."""
    pspec = sh.param_specs(state["params"], mesh, fsdp=tc.fsdp)
    specs = {
        "params": pspec,
        "opt": {
            "m": pspec,
            "v": pspec,
            "count": P(),
        },
        "step": P(),
    }
    if "gc_residual" in state:
        # residuals mirror params except non-compressible leaves, which are
        # scalar placeholders -> P()
        specs["gc_residual"] = jax.tree.map(
            lambda leaf, s: s if np.ndim(leaf) == len(s) else P(),
            state["gc_residual"], pspec,
        )
    return specs


def batch_specs(batch_shapes: dict[str, Any], mesh: Mesh):
    axes = tuple(mesh.axis_names)
    return {
        k: sh.data_batch_spec(axes, v.ndim, dim0=v.shape[0], mesh=mesh)
        for k, v in batch_shapes.items()
    }


def _microbatch(batch: dict, n_micro: int, mesh: Mesh) -> dict:
    """(B, ...) -> (n_micro, B/n_micro, ...) with a DP sharding constraint.

    Uses the trace-time `logical` hint so manual axes (inside the
    GradCompress pod shard_map) are filtered automatically."""

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        y = x.reshape(n_micro, b // n_micro, *x.shape[1:])
        return sh.logical(y, None, "batch", *([None] * (y.ndim - 2)))

    return jax.tree.map(reshape, batch)


def make_train_step(api: ModelAPI, mesh: Mesh, tc: TrainConfig):
    """Build the jit-able train step: (state, batch) -> (state, metrics).

    The caller jits it with in/out shardings from state_specs/batch_specs.
    """
    n_micro = tc.microbatches
    # one plan object from config to kernel; the scalar compress_keep /
    # codec_backend fields are uniform-plan shims
    plan = plan_lib.as_plan(tc.plan, keep=tc.compress_keep,
                            backend=tc.codec_backend, codec=tc.codec) \
        if tc.remat == "compressed" else None

    def loss_fn(params, mb):
        kw = {"plan": plan} if plan is not None else {}
        loss, metrics = api.loss(params, mb, remat=tc.remat, **kw)
        return loss, metrics

    def accumulate_grads(params, batch):
        """Scan microbatches; returns (mean grads f32, mean loss).

        Each microbatch's grads are constrained to the PARAM sharding before
        accumulation: the partial-sum -> sharded transition then lowers to a
        reduce-scatter instead of the tuple-all-reduce(+slice) XLA otherwise
        emits per microbatch (measured 2x wire on deepseek-v2 multi-pod,
        EXPERIMENTS.md §Perf).
        """
        micro = _microbatch(batch, n_micro, mesh)
        pspec = sh.param_specs(params, mesh, fsdp=tc.fsdp)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            g_acc, loss_acc = acc
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            # bf16 on the wire (halves the per-layer reduce volume), f32 in
            # the accumulator — standard mixed-precision DP practice
            grads = jax.tree.map(lambda g: g.astype(tc.grad_reduce_dtype), grads)
            grads = jax.tree.map(lambda g, s: sh.constrain(g, s), grads, pspec)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro, g_acc, grads
            )
            return (g_acc, loss_acc + loss / n_micro), None

        (grads, loss), _ = jax.lax.scan(body, (zero, 0.0), micro)
        return grads, loss

    if tc.grad_compress and "pod" in mesh.axis_names:
        gc_cfg = grad_comp.GradCompressConfig(keep=tc.grad_compress_keep)

        def per_pod(params, residual, batch):
            grads, loss = accumulate_grads(params, batch)
            grads, new_residual = grad_comp.exchange_compressed(
                grads, residual, gc_cfg, axis="pod"
            )
            loss = jax.lax.pmean(loss, "pod")
            return grads, new_residual, loss

        pod_grads = mesh_lib.shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(P(), P(), P("pod")),
            out_specs=(P(), P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )

        def step(state, batch):
            grads, new_residual, loss = pod_grads(
                state["params"], state["gc_residual"], batch
            )
            params, opt, om = adamw.apply_updates(
                state["params"], grads, state["opt"], tc.optimizer
            )
            new_state = {
                "params": params,
                "opt": opt,
                "step": state["step"] + 1,
                "gc_residual": new_residual,
            }
            return new_state, {"loss": loss, **om}

        return step

    def step(state, batch):
        grads, loss = accumulate_grads(state["params"], batch)
        params, opt, om = adamw.apply_updates(
            state["params"], grads, state["opt"], tc.optimizer
        )
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        return new_state, {"loss": loss, **om}

    return step


def jit_train_step(api: ModelAPI, mesh: Mesh, tc: TrainConfig, state, batch_like):
    """Convenience: jit with shardings + donated state."""
    step = make_train_step(api, mesh, tc)
    sspec = state_specs(state, mesh, tc)
    bspec = batch_specs(batch_like, mesh)
    to_shard = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec)
    return jax.jit(
        step,
        in_shardings=(to_shard(sspec), to_shard(bspec)),
        out_shardings=(to_shard(sspec), None),
        donate_argnums=(0,),
    )
