"""Shared pytest config.

Guard: tests must not leak jax_enable_x64 into the process (it breaks conv
dtype matching in every other module). The dry-run's 512-device flag is also
deliberately NOT set here — smoke tests run on the single real CPU device.
"""
import jax
import pytest


@pytest.fixture(autouse=True)
def _no_x64_leak():
    assert not jax.config.jax_enable_x64, "a test leaked jax_enable_x64=True"
    yield
