"""Per-architecture smoke tests: reduced config of each family, one forward +
loss + (where defined) decode step on CPU. Output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, registry
from repro.models import api as model_api

B, S = 2, 32


def _batch(api, key=0):
    cfg = api.cfg
    rng = np.random.default_rng(key)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    labels = np.roll(toks, -1, axis=1)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq_len or 16, cfg.d_model)), jnp.bfloat16
        )
        batch["labels"] = jnp.asarray(labels)
    elif cfg.frontend == "vision_stub":
        pf = 16
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, pf, cfg.d_model)), jnp.bfloat16
        )
        batch["labels"] = jnp.asarray(
            np.concatenate([np.full((B, pf), -1, np.int32), labels], axis=1)
        )
    else:
        batch["labels"] = jnp.asarray(labels)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_api(request):
    api = model_api.build_reduced(request.param)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def test_forward_and_loss(arch_api):
    api, params = arch_api
    batch = _batch(api)
    logits = api.forward(params, batch)
    v = api.cfg.vocab_size
    assert logits.shape[0] == B and logits.shape[-1] == v
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = api.loss(params, batch)
    assert jnp.isfinite(loss) and float(loss) > 0.0
    # loss should be near log(vocab) at random init
    assert float(loss) < 2.5 * np.log(v)


def test_grads_finite(arch_api):
    api, params = arch_api
    batch = _batch(api)
    g = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    finite = jax.tree.map(lambda x: bool(jnp.all(jnp.isfinite(x))), g)
    assert all(jax.tree.leaves(finite))


def test_decode_matches_forward(arch_api):
    """Greedy next-token logits from decode_step == teacher-forced forward.

    MoE archs run in f32: in bf16 the router's top-k can legitimately flip on
    near-tie logits between batched and single-token shapes (rounding), which
    is expected MoE behaviour, not a decode bug — f32 parity is the invariant.
    """
    api, params = arch_api
    if api.decode_step is None:
        pytest.skip("encoder-decoder: decode covered by whisper-specific test")
    cfg = api.cfg
    if cfg.family == "moe":
        # f32 + dropless forward: capacity drops in the batched forward are
        # legitimate MoE behaviour but break exact parity with dropless decode
        import dataclasses
        api = model_api.build(api.arch_id, dataclasses.replace(cfg, moe_dropless=True))
        params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        cache = api.init_cache(B, 32, dtype=jnp.float32)
        atol = 1e-3
    else:
        cache = api.init_cache(B, 32)
        atol = 0.15
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 16)).astype(np.int32))
    full = api.forward(params, {"tokens": toks}, remat="none")  # (B, 16, V)
    logits = None
    for t in range(16):
        logits, cache = api.decode_step(params, toks[:, t], cache, jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        atol=atol, rtol=0.05,
    )


def test_all_shapes_have_plan():
    """Every (arch, shape) cell either yields input specs or a documented skip."""
    n_ok, n_skip = 0, 0
    for arch_id in ARCH_IDS:
        api = model_api.build(arch_id)
        for shape in SHAPES:
            ok, why = api.cfg.shape_supported(shape)
            if not ok:
                assert why, f"{arch_id}/{shape} skipped without a reason"
                n_skip += 1
                continue
            specs = api.input_specs(shape)
            assert all(
                isinstance(s, jax.ShapeDtypeStruct)
                for s in jax.tree.leaves(specs)
            )
            n_ok += 1
    assert n_ok + n_skip == len(ARCH_IDS) * len(SHAPES) == 40
    assert n_skip == 9  # 8x long_500k (full attention) + whisper decode_32k


def test_param_counts_sane():
    """Analytic parameter totals are within tolerance of the advertised size."""
    expected = {
        "deepseek_v2_236b": 236e9,
        "moonshot_v1_16b_a3b": 16e9,
        "nemotron_4_340b": 340e9,
        "yi_6b": 6e9,
        "qwen2_0_5b": 0.5e9,
        "command_r_plus_104b": 104e9,
        "llava_next_mistral_7b": 7e9,
        "zamba2_2_7b": 2.7e9,
        "rwkv6_1_6b": 1.6e9,
    }
    for arch_id, target in expected.items():
        total = get_config(arch_id).param_counts()["total"]
        assert 0.5 * target < total < 1.8 * target, (arch_id, total, target)


def test_moe_active_less_than_total():
    for arch_id in ("deepseek_v2_236b", "moonshot_v1_16b_a3b"):
        pc = get_config(arch_id).param_counts()
        assert pc["active"] < 0.25 * pc["total"]
