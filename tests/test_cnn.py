"""CNN substrate tests: forwards, compression hooks, reconstruction sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import natural_images, shapes_dataset
from repro.models import cnn


@pytest.fixture(scope="module")
def img():
    return jnp.asarray(natural_images(1, 2, 32, 32))


@pytest.mark.parametrize("name", ["vgg16_bn", "resnet50", "mobilenet_v1", "mobilenet_v2"])
def test_cnn_forward_shapes(name, img):
    init, apply = cnn.MODELS[name]
    params = init(jax.random.PRNGKey(0))
    out = apply(params, img)
    assert out.shape == (2, 21)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_yolo_backbone_forward(img):
    init, apply = cnn.MODELS["yolov3_backbone"]
    params = init(jax.random.PRNGKey(0))
    out = apply(params, img)
    assert out.shape == (2, 1, 1, 1024)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_compression_changes_activations_slightly(img):
    init, apply = cnn.MODELS["tiny_cnn"]
    params = init(jax.random.PRNGKey(1), cin=3)
    clean = apply(params, img)
    sched = cnn.CompressionSchedule(n_layers=3)
    comp = apply(params, img, sched, cnn.FusionStats())
    # compression is lossy but mild: logits stay close, same argmax mostly
    assert bool(jnp.all(jnp.isfinite(comp)))
    rel = float(jnp.linalg.norm(comp - clean) / (jnp.linalg.norm(clean) + 1e-9))
    assert rel < 0.5


def test_fusion_stats_accounting(img):
    init, apply = cnn.MODELS["tiny_cnn"]
    params = init(jax.random.PRNGKey(2), cin=3)
    stats = cnn.FusionStats()
    apply(params, img, cnn.CompressionSchedule(n_layers=2), stats)
    assert len(stats.layers) == 3
    # first two compressed, third pass-through (ratio 1)
    rs = [float(r) for r in stats.ratios()]
    assert rs[0] < 1.0 and rs[1] < 1.0 and rs[2] == 1.0
    assert 0.0 < float(stats.overall_ratio()) <= 1.0


def test_relu_sparsity_vs_dense():
    """Paper motivation: leaky-ReLU (yolo) feature maps are dense, ReLU sparse."""
    x = jnp.asarray(natural_images(3, 1, 16, 16))
    init_v, apply_v = cnn.MODELS["tiny_cnn"]
    params = init_v(jax.random.PRNGKey(3), cin=3)
    h = cnn.relu(cnn.bn(params["b1"], cnn.conv(params["c1"], x)))
    dense = cnn.leaky_relu(cnn.bn(params["b1"], cnn.conv(params["c1"], x)))
    assert float(jnp.mean(h == 0)) > 0.2
    assert float(jnp.mean(dense == 0)) < 0.05


def test_shapes_dataset():
    imgs, labels = shapes_dataset(0, 64)
    assert imgs.shape == (64, 32, 32, 1) and labels.shape == (64,)
    assert set(np.unique(labels)) <= {0, 1, 2, 3}
    # classes are balanced-ish and images non-trivial
    assert imgs.std() > 0.1
