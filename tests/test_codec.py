"""Quantization + encoding + end-to-end codec tests (paper Eq. 7-10, Fig. 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compressor, encode as encode_lib, quantize as quant_lib
from repro.core import dct as dct_lib


def natural_image(rng, h, w, alpha=1.5):
    """1/f^alpha spectrum image — natural-image statistics for codec tests."""
    fy = np.fft.fftfreq(h)[:, None]
    fx = np.fft.fftfreq(w)[None, :]
    f = np.sqrt(fy**2 + fx**2)
    f[0, 0] = 1.0
    spec = rng.standard_normal((h, w)) + 1j * rng.standard_normal((h, w))
    img = np.fft.ifft2(spec / f**alpha).real
    img = (img - img.mean()) / (img.std() + 1e-9)
    return img


# --------------------------- quantization ----------------------------------

def test_qtable_levels_monotone():
    """Aggressive levels must have larger table values everywhere."""
    t0 = quant_lib.qtable_for_level(0)
    t3 = quant_lib.qtable_for_level(3)
    assert (t0 >= t3).all() and t0.mean() > t3.mean()


def test_qtable_lowfreq_smaller():
    t = quant_lib.qtable_for_level(1)
    assert t[0, 0] < t[7, 7]
    assert t[:2, :2].mean() < t[6:, 6:].mean()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8, 12]))
def test_minmax_quant_bounds_error(seed, bits):
    """Eq. 7/10 roundtrip error <= half a quantization step."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-5, 7, (8, 8)))
    fmin, fmax = quant_lib.compute_range(x)
    p = quant_lib.QuantParams(fmin, fmax, bits)
    q1 = quant_lib.quantize_minmax(x, p)
    back = quant_lib.dequantize_minmax(q1, p)
    step = float(fmax - fmin) / p.imax
    assert float(jnp.max(jnp.abs(back - x))) <= step / 2 + 1e-9


def test_constant_tensor_quant_safe():
    x = jnp.full((8, 8), 2.5)
    fmin, fmax = quant_lib.compute_range(x)
    assert float(fmax) > float(fmin)  # degenerate range guarded


# --------------------------- encoding --------------------------------------

def test_encode_decode_identity():
    rng = np.random.default_rng(0)
    q2 = jnp.asarray(rng.integers(-20, 20, (10, 8, 8)))
    q2 = jnp.where(jnp.abs(q2) < 12, 0, q2)  # sparsify
    enc = encode_lib.encode_blocks(q2)
    dec = encode_lib.decode_blocks(enc)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(q2, dtype=np.float32))


def test_paper_codec_bits_accounting():
    q2 = np.zeros((2, 8, 8))
    q2[0, 0, 0] = 5
    q2[1, 1, 1] = -3
    # 2 blocks * 64 index bits + 2 nnz * 8 bits
    assert encode_lib.paper_codec_bits(q2, value_bits=8) == 2 * 64 + 2 * 8


def test_flip_storage_improves_utilization():
    """Fig. 5: flipping odd blocks packs banks better for corner-heavy data."""
    rng = np.random.default_rng(1)
    # top-heavy blocks (zeros bottom-right) — like quantized DCT coefficients
    idx = np.zeros((16, 8, 8), dtype=bool)
    for b in range(16):
        nr = rng.integers(2, 6)
        for r in range(nr):
            idx[b, r, : rng.integers(2, 8 - r)] = True
    u_flip = encode_lib.sram_utilization(idx, flip=True)
    u_noflip = encode_lib.sram_utilization(idx, flip=False)
    assert u_flip >= u_noflip


def test_rle_and_csr_sane():
    x = np.zeros((8, 8))
    x[0, 0] = 1.0
    assert encode_lib.rle_codec_bits(x) < encode_lib.dense_bits(x)
    assert encode_lib.csr_codec_bits(x) < encode_lib.dense_bits(x)
    assert encode_lib.entropy_bound_bits(x) < encode_lib.dense_bits(x)


def _sram_bank_occupancy_loop(index, flip=True):
    """The original per-block Python loop — oracle for the vectorized form."""
    idx = np.asarray(index, dtype=bool).reshape(-1, 8, 8)
    fills = np.zeros(8, dtype=np.int64)
    for b, blk in enumerate(idx):
        rows = blk[::-1] if (flip and b % 2 == 1) else blk
        fills += rows.sum(axis=1)
    depth = int(fills.max()) if len(idx) else 0
    return depth, int(idx.sum())


@settings(max_examples=30, deadline=None)
@given(nblocks=st.integers(0, 9), flip=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_sram_bank_occupancy_vectorized_exact_parity(nblocks, flip, seed):
    """Vectorized bank model == per-block loop, bit for bit — including odd
    block counts (whose last block IS a flip row) and the empty batch."""
    rng = np.random.default_rng(seed)
    idx = rng.random((nblocks, 8, 8)) < rng.random()
    assert encode_lib.sram_bank_occupancy(idx, flip=flip) == \
        _sram_bank_occupancy_loop(idx, flip=flip)


def test_sram_bank_occupancy_empty_and_all_zero():
    assert encode_lib.sram_bank_occupancy(np.zeros((0, 8, 8), bool)) == (0, 0)
    assert encode_lib.sram_bank_occupancy(np.zeros((3, 8, 8), bool)) == (0, 0)
    assert encode_lib.sram_utilization(np.zeros((3, 8, 8), bool)) == 1.0


def test_sram_bank_occupancy_does_not_mutate_input():
    idx = np.ones((4, 8, 8), dtype=bool)
    idx.setflags(write=False)  # the flip must not write through the input
    assert encode_lib.sram_bank_occupancy(idx, flip=True) == (32, 256)


# --------------------------- masked-lane contract --------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), level=st.integers(0, 3))
def test_paper_decompress_invariant_to_masked_lane_garbage(seed, level):
    """The paper's hardware never stores values under a zero index bit, so
    our dense carrier's payload there is garbage BY CONTRACT (encode.py).
    Decode and storage accounting must be invariant to corrupting it."""
    from dataclasses import replace

    from repro import codec

    rng = np.random.default_rng(seed)
    x = jnp.asarray(natural_image(rng, 24, 16), jnp.float32)
    c = codec.paper_compress(x, compressor.CompressionPolicy(level=level))
    idx = np.asarray(c.index)
    assert not idx.all(), "need at least one masked lane to corrupt"
    garbage = rng.integers(-(2**20), 2**20, idx.shape)
    values = np.where(idx, np.asarray(c.values), garbage).astype(np.int32)
    corrupted = replace(c, values=jnp.asarray(values))

    np.testing.assert_array_equal(
        np.asarray(codec.paper_decompress(c)),
        np.asarray(codec.paper_decompress(corrupted)))
    assert int(codec.paper_storage_bits(c)) == \
        int(codec.paper_storage_bits(corrupted))
    # the gated carrier view is the sanctioned read path for accounting
    np.testing.assert_array_equal(
        np.asarray(codec.paper_masked_values(corrupted)),
        np.asarray(codec.paper_masked_values(c)))
    assert encode_lib.paper_codec_bits(
        np.asarray(codec.paper_masked_values(corrupted))) == \
        encode_lib.paper_codec_bits(np.asarray(codec.paper_masked_values(c)))


# --------------------------- end-to-end ------------------------------------

@pytest.mark.parametrize("level", [0, 1, 2, 3])
def test_roundtrip_error_bounded_and_monotone(level):
    rng = np.random.default_rng(42)
    x = jnp.asarray(natural_image(rng, 32, 32), jnp.float32)
    pol = compressor.CompressionPolicy(level=level)
    y = compressor.roundtrip(x, pol)
    err = float(jnp.sqrt(jnp.mean((y - x) ** 2)))
    sig = float(jnp.sqrt(jnp.mean(x**2)))
    assert err / sig < 0.5  # reconstructs the signal


def test_gentler_level_lower_error():
    rng = np.random.default_rng(43)
    x = jnp.asarray(natural_image(rng, 64, 64), jnp.float32)
    errs = []
    for level in range(4):
        y = compressor.roundtrip(x, compressor.CompressionPolicy(level=level))
        errs.append(float(jnp.mean((y - x) ** 2)))
    assert errs[3] < errs[0]  # gentle (deep-layer) level more accurate


def test_natural_image_compresses_well():
    """1/f images: paper reports ~9-35%% ratios for early layers."""
    rng = np.random.default_rng(44)
    x = jnp.asarray(natural_image(rng, 128, 128), jnp.float32)
    c = compressor.compress(x, compressor.CompressionPolicy(level=0))
    ratio = float(compressor.compression_ratio(c, orig_value_bits=16))
    assert ratio < 0.45


def test_white_noise_compresses_poorly():
    """No frequency structure -> ratio should be much worse than 1/f."""
    rng = np.random.default_rng(45)
    x = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    c_noise = compressor.compress(x, compressor.CompressionPolicy(level=3))
    nat = jnp.asarray(natural_image(rng, 128, 128), jnp.float32)
    c_nat = compressor.compress(nat, compressor.CompressionPolicy(level=3))
    assert float(compressor.compression_ratio(c_noise)) > float(
        compressor.compression_ratio(c_nat)
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), keep=st.sampled_from([2, 3, 4, 6, 8]))
def test_truncated_roundtrip_property(seed, keep):
    """TPU path: shape preserved, error bounded, jit-able."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(natural_image(rng, 24, 16), jnp.float32)
    y = jax.jit(lambda a: compressor.roundtrip_truncated(a, keep))(x)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert bool(jnp.all(jnp.isfinite(y)))
    if keep == 8:
        # full corner = int8 quantization only; tight error on unit-scale data
        assert float(jnp.max(jnp.abs(y - x))) < 0.35


def test_truncated_bytes_accounting():
    rng = np.random.default_rng(46)
    x = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    c = compressor.compress_truncated(x, keep=4)
    assert c.coefs.dtype == jnp.int8
    assert c.coefs.shape[-2:] == (4, 4)
    # 16 int8 + 4 header bytes (f32 scale only — the zero plane is always
    # zero and not charged) per 64 elements = 0.3125 B/elem vs 2 B/elem bf16
    assert abs(c.nbytes_per_element() - 20 / 64) < 1e-9


def test_compress_under_jit_and_grad():
    """Grad flows through the scale path; round() is piecewise-constant
    (zero grad), matching the hardware's non-differentiable quantizer."""
    rng = np.random.default_rng(47)
    x = jnp.asarray(natural_image(rng, 16, 16), jnp.float32)

    def loss(a):
        return jnp.sum(compressor.roundtrip_truncated(a, 4) ** 2)

    g = jax.grad(loss)(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))
