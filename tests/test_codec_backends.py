"""Backend parity tests for the unified codec layer (repro.codec).

Pins the contract the dispatch refactor relies on: the `reference` (pure-JAX
einsum) and `pallas` (fused kernels, interpret mode on CPU) backends agree
bitwise on packed int8 output and within tolerance after roundtrip, across
non-square, padded (non-8-aligned), and batched-leading-dim shapes — so
flipping the default backend on TPU cannot change results beyond float
noise.  Runs without hypothesis (plain parametrize) so CI always covers it.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codec

BACKENDS = ("reference", "pallas")

# non-square, unaligned (forces edge padding), and batched-leading-dim shapes
SHAPES = [(16, 16), (24, 16), (40, 264), (13, 21), (30, 17),
          (3, 24, 16), (2, 5, 16, 32), (2, 3, 11, 19)]


def _rand(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


# --------------------------- truncated scheme -------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("keep", [2, 4, 8])
def test_truncated_packed_parity(shape, keep):
    """Backends agree bitwise on the packed int8 coefficients and scales."""
    x = _rand(shape, seed=sum(shape) + keep)
    cr = codec.compress(x, keep, backend="reference")
    cp = codec.compress(x, keep, backend="pallas")
    assert cr.coefs.shape == cp.coefs.shape and cr.coefs.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(cr.coefs), np.asarray(cp.coefs))
    np.testing.assert_array_equal(np.asarray(cr.scale), np.asarray(cp.scale))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("keep", [2, 4, 8])
def test_truncated_roundtrip_parity(shape, keep):
    x = _rand(shape, seed=sum(shape) + keep + 1)
    yr = codec.roundtrip(x, keep, backend="reference")
    yp = codec.roundtrip(x, keep, backend="pallas")
    assert yr.shape == x.shape and yp.shape == x.shape
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yp), atol=1e-5)
    if keep == 8:  # full corner: int8 quantization error only
        assert float(jnp.max(jnp.abs(yr - x))) < 0.35


@pytest.mark.parametrize("backend", BACKENDS)
def test_cross_backend_decompress(backend):
    """A container compressed on one backend decompresses on the other."""
    other = "pallas" if backend == "reference" else "reference"
    x = _rand((3, 24, 16), seed=7)
    c = codec.compress(x, 4, backend=backend)
    ya = codec.decompress(c, backend=backend)
    yb = codec.decompress(c, backend=other)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-5)


def test_blocks_layer_shapes_and_parity():
    x = _rand((2, 5, 16, 32), seed=9)
    qr, sr = codec.compress_blocks(x, 4, backend="reference")
    qp, sp = codec.compress_blocks(x, 4, backend="pallas")
    assert qr.shape == (2, 5, 2, 4, 4, 4) and qr.dtype == jnp.int8
    assert sr.shape == (2, 5, 2, 4)
    np.testing.assert_array_equal(np.asarray(qr), np.asarray(qp))
    yr = codec.decompress_blocks(qr, sr, backend="reference")
    yp = codec.decompress_blocks(qp, sp, backend="pallas")
    assert yr.shape == x.shape
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yp), atol=1e-5)


def test_unaligned_plane_rejected_at_blocks_layer():
    with pytest.raises(ValueError):
        codec.compress_blocks(_rand((13, 16)), 4)


# ----------------------------- paper scheme ---------------------------------

@pytest.mark.parametrize("level", [0, 1, 2, 3])
def test_paper_scheme_parity(level):
    x = _rand((3, 24, 16), seed=40 + level)
    pol = codec.CompressionPolicy(level=level)
    cr = codec.paper_compress(x, pol, backend="reference")
    cp = codec.paper_compress(x, pol, backend="pallas")
    np.testing.assert_array_equal(np.asarray(cr.values), np.asarray(cp.values))
    np.testing.assert_array_equal(np.asarray(cr.index), np.asarray(cp.index))
    yr = codec.paper_decompress(cr, backend="reference")
    yp = codec.paper_decompress(cp, backend="pallas")
    assert yr.shape == x.shape
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yp), atol=1e-5)
    r = float(codec.compression_ratio(cr))
    assert 0.0 < r  # accounting stays well-defined on both backends


@pytest.mark.parametrize("backend", BACKENDS)
def test_dct_idct_roundtrip(backend):
    x = _rand((4, 16, 24), seed=11)
    z = codec.dct2(x, backend=backend)
    back = codec.idct2(z, backend=backend)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


def test_quant_pack_parity():
    x = _rand((32, 64), seed=12) * 10.0
    fmin, fmax = float(jnp.min(x)), float(jnp.max(x))
    qr, ir, nr = codec.quant_pack(x, fmin, fmax, level=1, backend="reference")
    qp_, ip, np_ = codec.quant_pack(x, fmin, fmax, level=1, backend="pallas")
    np.testing.assert_array_equal(np.asarray(qr), np.asarray(qp_))
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ip))
    assert int(nr) == int(np_)


# --------------------------- dispatch policy --------------------------------

def test_auto_selection_on_cpu_is_reference():
    assert jax.default_backend() != "tpu"  # CI precondition
    assert codec.resolve_backend_name(None) == "reference"
    assert codec.resolve_backend_name("pallas") == "pallas"


def test_env_override(monkeypatch):
    monkeypatch.setenv(codec.dispatch.ENV_BACKEND, "pallas")
    assert codec.resolve_backend_name(None) == "pallas"
    monkeypatch.delenv(codec.dispatch.ENV_BACKEND)
    assert codec.resolve_backend_name(None) == "reference"


def test_set_default_backend_override():
    codec.set_default_backend("pallas")
    try:
        assert codec.resolve_backend_name(None) == "pallas"
        x = _rand((16, 16), seed=13)
        y = codec.roundtrip(x, 8)  # runs the pallas (interpret) path
        assert y.shape == x.shape
    finally:
        codec.set_default_backend(None)
    assert codec.resolve_backend_name(None) == "reference"


def test_unknown_backend_rejected():
    with pytest.raises(KeyError):
        codec.get_backend("no_such_backend")
    with pytest.raises(KeyError):
        codec.set_default_backend("no_such_backend")


def test_interpret_resolution():
    # auto: interpret everywhere but TPU; env forces either way
    assert codec.resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert codec.resolve_interpret(True) is True
    assert codec.resolve_interpret(False) is False
    os.environ[codec.dispatch.ENV_INTERPRET] = "0"
    try:
        assert codec.resolve_interpret(None) is False
    finally:
        del os.environ[codec.dispatch.ENV_INTERPRET]


# ------------------------ consumer-facing contracts -------------------------

def test_storage_stats_accounting():
    x = _rand((16, 16), seed=14)
    c = codec.compress(x, 4)
    stats = codec.storage_stats(c)
    # 4 tiles * (16 int8 + 4 header bytes: f32 scale only, the always-zero
    # zero-point plane is not charged) vs 256 elements * 2 B
    assert abs(stats["bytes_per_element"] - 20 / 64) < 1e-9
    assert abs(stats["ratio"] - (4 * (16 * 8 + 32)) / (256 * 16)) < 1e-9


def test_gradient_flows_through_reference_backend():
    x = _rand((16, 16), seed=15)

    def loss(a):
        return jnp.sum(codec.roundtrip(a, 4, backend="reference") ** 2)

    g = jax.grad(loss)(x)
    assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))


def test_compressor_facade_routes_through_codec():
    from repro.core import compressor

    x = _rand((24, 16), seed=16)
    c = compressor.compress_truncated(x, keep=4)
    assert isinstance(c, codec.TruncatedCompressed)
    assert c.coefs.dtype == jnp.int8 and c.coefs.shape[-2:] == (4, 4)
    assert abs(c.nbytes_per_element() - 20 / 64) < 1e-9
    y = compressor.decompress_truncated(c)
    assert y.shape == x.shape
    pol = compressor.CompressionPolicy(level=1)
    assert isinstance(compressor.compress(x, pol), codec.Compressed)


def test_kv_blocks_route_through_codec():
    from repro.core import kv_cache as KV

    x = _rand((2, 32, 16), seed=17)
    q, s = KV.compress_kv_blocks(x, 4)
    qc, sc = codec.compress_blocks(x, 4)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qc))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sc))
    back = KV.decompress_kv_blocks(q, s, jnp.float32)
    assert back.shape == x.shape
