"""Codec-family contract: plane trees, pack/unpack exactness, byte
accounting, plan threading, and the pinned proof that the refactor left the
default dct path bitwise identical.

The pinned literals in `BASELINE` were captured from the pre-refactor tree
(commit 29d5032) with the exact serve configuration `_baseline_serve` uses:
greedy tokens of 8 requests through a 4-slot paged pool, plus the pool's
analytic byte stats.  The refactored cache MUST reproduce them token for
token and byte for byte — the dct family is the old layout behind a new
seam, not a new codec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codec import api as codec_api
from repro.codec import families as families_lib
from repro.codec import plan as plan_lib
from repro.core import encode as encode_lib
from repro.core import kv_cache as kvc
from repro.models import api as model_api
from repro.serve import engine as E

BLOCK = 8


def _quantized_blocks(rng, shape=(3, 2, 4), keep=5, zero_frac=0.5):
    """Random quantized tiles in the (..., nh, k, k) + scale form the block
    codec emits: int8 coefficients with a realistic zero fraction, and a few
    all-zero tiles (zero scale) mixed in."""
    q = rng.integers(-127, 128, shape + (keep, keep)).astype(np.int8)
    q = np.where(rng.random(q.shape) < zero_frac, 0, q)
    scale = rng.random(shape).astype(np.float32) * 3.0
    dead = rng.random(shape) < 0.15
    q = np.where(dead[..., None, None], 0, q)
    scale = np.where(dead, 0.0, scale)
    return jnp.asarray(q), jnp.asarray(scale)


# ---------------------------------------------------------------------------
# Registry + plane tree
# ---------------------------------------------------------------------------

def test_registry_declares_three_families():
    assert families_lib.available_families() == ["asc", "bitplane", "dct"]
    assert families_lib.get_family(None).name == "dct"  # None => default
    with pytest.raises(KeyError, match="unknown codec family"):
        families_lib.get_family("zstd")


def test_every_family_declares_packed_carrier():
    for name in families_lib.available_families():
        fam = families_lib.get_family(name)
        specs = {s.name: s for s in fam.plane_specs(5, 32)}
        assert "packed" in specs
        assert specs["packed"].block_shape == (4, 5, 5)  # (hd/8, k, k)
        assert specs["packed"].dtype == jnp.int8


def test_plane_block_ndims_consistent():
    # one global name -> rank table (what sharding dispatches on)
    nd = families_lib.plane_block_ndims()
    assert nd["packed"] == 3 and nd["scale"] == 1
    assert nd["bpmask"] == 2 and nd["blen"] == 1 and nd["sexp"] == 1


def test_register_rejects_conflicting_plane_rank():
    class Bad(families_lib.CodecFamily):
        name = "bad"

        def plane_specs(self, keep, head_dim):
            return (families_lib.PlaneSpec("packed", jnp.int8, (1, keep, keep)),
                    families_lib.PlaneSpec("scale", jnp.float32, (1, 2)))

    with pytest.raises(ValueError, match="already registered with rank"):
        families_lib.register_family(Bad())
    assert "bad" not in families_lib.available_families()


# ---------------------------------------------------------------------------
# Pack/unpack exactness + byte accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["dct", "bitplane", "asc"])
@pytest.mark.parametrize("keep", [2, 5, 8])
def test_pack_unpack_int8_exact(name, keep):
    """The int8 coefficient blocks survive every family's plane layout
    bitwise (scales may round where the family declares an adaptive
    header — the coefficients never do)."""
    fam = families_lib.get_family(name)
    q, scale = _quantized_blocks(np.random.default_rng(0), keep=keep)
    planes = fam.pack(q, scale, keep)
    assert set(p.name for p in fam.plane_specs(keep, 32)) == set(planes)
    q2, scale2 = fam.unpack(planes, keep)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
    if name in ("dct", "bitplane"):
        np.testing.assert_array_equal(np.asarray(scale2), np.asarray(scale))


def test_asc_scale_error_bounded():
    fam = families_lib.get_family("asc")
    q, scale = _quantized_blocks(np.random.default_rng(1), keep=4)
    _, scale2 = fam.unpack(fam.pack(q, scale, 4), 4)
    s, s2 = np.asarray(scale), np.asarray(scale2)
    # zero scales reconstruct exactly (reserved code); the rest within an
    # eighth of an octave: rel err < 2**(1/16) - 1
    np.testing.assert_array_equal(s2[s == 0], 0.0)
    live = s > 0
    rel = np.abs(s2[live] - s[live]) / s[live]
    assert rel.max() <= 2 ** (1 / 16) - 1 + 1e-6


@pytest.mark.parametrize("name", ["dct", "bitplane", "asc"])
@pytest.mark.parametrize("keep", [2, 5, 8])
def test_analytic_upper_bounds_measured(name, keep):
    fam = families_lib.get_family(name)
    for zero_frac in (0.0, 0.5, 1.0):
        q, _ = _quantized_blocks(np.random.default_rng(2), keep=keep,
                                 zero_frac=zero_frac)
        bits = np.asarray(fam.measured_tile_bits(q))
        assert bits.shape == q.shape[:-2]
        assert (bits <= 8 * fam.analytic_tile_bytes(keep)).all(), \
            (name, keep, zero_frac)


def test_bitplane_blen_matches_numpy_rle_reference():
    """The bitplane family's stored per-tile length is EXACTLY the repo's
    one RLE accounting (`core.encode.rle_codec_bits`), including the
    all-zero and fully-dense edge cases — reused, not reimplemented."""
    fam = families_lib.get_family("bitplane")
    rng = np.random.default_rng(3)
    keep = 5
    tiles = [np.zeros((keep, keep), np.int8),                  # all zero
             rng.integers(1, 127, (keep, keep)).astype(np.int8)]  # dense
    for zf in (0.2, 0.6, 0.9, 0.97):
        t = rng.integers(-127, 128, (keep, keep)).astype(np.int8)
        tiles.append(np.where(rng.random(t.shape) < zf, 0, t))
    q = jnp.asarray(np.stack(tiles))
    planes = fam.pack(q, jnp.ones(len(tiles), jnp.float32), keep)
    blen = np.asarray(planes["blen"])
    for i, t in enumerate(tiles):
        want = encode_lib.rle_codec_bits(t.reshape(-1), fam.VALUE_BITS,
                                         fam.RUN_BITS)
        assert int(blen[i]) == want, (i, int(blen[i]), want)


def test_rle_tiles_matches_numpy_on_long_runs():
    # saturated-run edge: runs far beyond maxrun=31, and a trailing run
    rng = np.random.default_rng(4)
    for n in (31, 32, 63, 200):
        x = np.zeros(n, np.int8)
        x[0] = 1  # long trailing zero run
        rows = [x, np.zeros(n, np.int8),
                rng.integers(-5, 6, n).astype(np.int8)]
        got = np.asarray(encode_lib.rle_codec_bits_tiles(
            jnp.asarray(np.stack(rows)), 8, 5))
        for r, g in zip(rows, got):
            assert int(g) == encode_lib.rle_codec_bits(r, 8, 5)


def test_family_compress_roundtrip_through_backend():
    """compress/decompress entry points: planes in, activations out, equal
    to the raw block-codec roundtrip for every family (bitwise for
    dct/bitplane; asc within its scale-step bound)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 24, 16)).astype(np.float32))
    q, scale = codec_api.compress_blocks(x, 4, backend="reference")
    want = codec_api.decompress_blocks(q, scale, backend="reference")
    for name in families_lib.available_families():
        fam = families_lib.get_family(name)
        planes = fam.compress(x, 4, backend="reference")
        got = fam.decompress(planes, 4, backend="reference")
        assert got.shape == want.shape
        if name == "asc":
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2 ** (1 / 16) - 1 + 1e-5,
                                       atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Plan threading: spec grammar, validation, budget solver over curves
# ---------------------------------------------------------------------------

def test_plan_spec_codec_roundtrip():
    spec = "0-1:keep=6,2-:keep=4+codec=bitplane"
    plan = plan_lib.CompressionPlan.from_spec(spec)
    pols = plan.policies(4)
    assert [p.codec for p in pols] == ["dct", "dct", "bitplane", "bitplane"]
    assert plan.to_spec() == spec  # codec= token survives the round trip


def test_plan_spec_errors_name_token_and_position():
    # "0-1:keep=6,2-:kep=4" — the bad token starts at character 14
    with pytest.raises(ValueError) as ei:
        plan_lib.CompressionPlan.from_spec("0-1:keep=6,2-:kep=4")
    msg = str(ei.value)
    assert "'kep=4'" in msg and "position 14" in msg

    # "0-:keep=4+codec=zstd" — unknown family rejected at parse, char 10
    with pytest.raises(ValueError) as ei:
        plan_lib.CompressionPlan.from_spec("0-:keep=4+codec=zstd")
    msg = str(ei.value)
    assert "'codec=zstd'" in msg and "position 10" in msg
    assert "asc" in msg  # names the families that DO exist


def test_layer_policy_rejects_unknown_codec():
    with pytest.raises(ValueError, match="unknown codec family 'nope'"):
        plan_lib.LayerPolicy(keep=4, codec="nope")


def test_with_codec_and_as_plan_override_everywhere():
    plan = plan_lib.as_plan("0-1:keep=6,2-:keep=4", codec="asc")
    assert all(p.codec == "asc" for p in plan.policies(4))
    # int / None spellings too
    assert all(p.codec == "bitplane"
               for p in plan_lib.as_plan(5, codec="bitplane").policies(3))


class _Cfg:
    n_layers = 4
    n_kv_heads = 2
    resolved_head_dim = 32


def test_layer_bytes_per_token_per_family():
    # hd=32 -> nh=4 tiles/head; 2 (K+V) * 2 heads * 4 tiles / 8 tokens
    # = 2 tiles/token; dct keep=4: tile 20 B -> 40 B/token
    f = plan_lib.CompressionPlan._layer_bytes_per_token
    assert f(_Cfg, plan_lib.LayerPolicy(keep=4)) == 40.0
    assert f(_Cfg, plan_lib.LayerPolicy(keep=4, codec="asc")) == 34.0
    bp = families_lib.get_family("bitplane").analytic_tile_bytes(4)
    assert f(_Cfg, plan_lib.LayerPolicy(keep=4, codec="bitplane")) == 2 * bp


def test_from_budget_curves_selects_mixed_plan():
    curves = [
        {"codec": "dct", "keep": 8, "ppl_delta": 0.01},
        {"codec": "dct", "keep": 4, "ppl_delta": 0.30},
        {"codec": "bitplane", "keep": 4, "ppl_delta": 0.25},
        {"codec": "asc", "keep": 3, "ppl_delta": 0.90},
        # dominated: costs more than dct@8 at worse quality -> off frontier
        {"codec": "bitplane", "keep": 8, "ppl_delta": 0.50},
    ]
    loose = plan_lib.CompressionPlan.from_budget(
        _Cfg, 64, 1e9, curves=curves)
    assert all(p.codec == "dct" and p.kv_keep == 8
               for p in loose.policies(4))

    # a budget that fits dct@8 on some layers but not all: the solver walks
    # the deepest layers down the frontier first
    per_layer8 = plan_lib.CompressionPlan._layer_bytes_per_token(
        _Cfg, plan_lib.LayerPolicy(keep=8))
    tail = _Cfg.n_layers * 2 * BLOCK * _Cfg.n_kv_heads * \
        _Cfg.resolved_head_dim * 2
    budget = (2.5 * per_layer8 + 1.5 * 34.0) * 64 + tail  # ~2-3 layers at dct@8
    mixed = plan_lib.CompressionPlan.from_budget(
        _Cfg, 64, budget, curves=curves)
    pols = mixed.policies(4)
    assert {(p.codec, p.kv_keep) for p in pols} > {("dct", 8)}  # truly mixed
    assert mixed.kv_cache_bytes(_Cfg, 64) <= budget
    # monotone: a smaller budget only ever moves layers DOWN the frontier
    frontier_rank = {("dct", 8): 0, ("bitplane", 4): 1, ("dct", 4): 2,
                     ("asc", 3): 3}
    tight = plan_lib.CompressionPlan.from_budget(
        _Cfg, 64, budget * 0.7, curves=curves)
    for a, b in zip(pols, tight.policies(4)):
        assert frontier_rank[(b.codec, b.kv_keep)] >= \
            frontier_rank[(a.codec, a.kv_keep)]

    with pytest.raises(ValueError, match="infeasible"):
        plan_lib.CompressionPlan.from_budget(_Cfg, 64, 1.0, curves=curves)


# ---------------------------------------------------------------------------
# Cache containers: segment planes follow the declaration
# ---------------------------------------------------------------------------

def test_segment_planes_follow_family_declaration():
    plan = plan_lib.as_plan("0-1:keep=6,2-:keep=4+codec=bitplane")
    cache = kvc.init_paged_cache(_Cfg, batch=2, max_seq=64, n_pages=16,
                                 plan=plan, dtype=jnp.float32)
    segs = cache.segments
    assert [s.codec for s in segs] == ["dct", "bitplane"]
    assert segs[0].page_keys == ("packed_k", "packed_v", "scale_k", "scale_v")
    assert segs[1].page_keys == ("blen_k", "blen_v", "bpmask_k", "bpmask_v",
                                 "packed_k", "packed_v", "scale_k", "scale_v")
    # paged plane geometry: (Lseg, P, Hkv) + block_shape
    assert segs[1].planes["bpmask_k"].shape == (2, 16, 2, 4, 2)
    assert segs[1].planes["blen_k"].shape == (2, 16, 2, 4)
    # analytic page bytes charge each segment's own family
    bp = families_lib.get_family("bitplane").analytic_tile_bytes(4)
    want = (2 * 2 * 2 * 4 * codec_api.tile_bytes(6)) + (2 * 2 * 2 * 4 * bp)
    assert cache.page_bytes() == want


def test_measured_cache_bytes_bounded_by_analytic():
    plan = plan_lib.as_plan("0-1:keep=6,2-:keep=4+codec=bitplane")
    cache = kvc.init_paged_cache(_Cfg, batch=2, max_seq=64, n_pages=16,
                                 plan=plan, dtype=jnp.float32)
    # empty pool: only the raw tails are resident
    tails = sum(int(np.prod(s.planes[n].shape)) * 4
                for s in cache.segments for n in kvc.TAIL_NAMES)
    assert kvc.measured_cache_bytes(cache) == tails


def test_tier_manager_mirrors_family_planes():
    """Host tier allocates each segment's OWN plane set (not the legacy
    dct 4-tuple) and the stage_out -> read_back round trip is bitwise for
    non-dct planes too."""
    from repro.serve import tiering
    plan = plan_lib.as_plan("0-1:keep=6,2-:keep=4+codec=asc")
    mk = lambda: kvc.init_paged_cache(_Cfg, batch=2, max_seq=64, n_pages=6,
                                      plan=plan, dtype=jnp.float32)
    tier = tiering.TierManager(jax.eval_shape(mk), host_pages=4)
    assert tier._page_keys[0] == ("packed_k", "packed_v",
                                  "scale_k", "scale_v")
    assert tier._page_keys[1] == ("packed_k", "packed_v",
                                  "sexp_k", "sexp_v")
    rng = np.random.default_rng(7)
    cache = jax.tree.map(
        lambda l: jnp.asarray(rng.standard_normal(l.shape) * 8)
        .astype(l.dtype), mk())
    ids = jnp.asarray(np.array([0, 1], np.int32))
    upd = kvc.paged_gather_slot(cache, jnp.int32(0), ids)
    hids = tier.alloc(2)
    tier.stage_out(hids, jax.tree.map(np.asarray, upd))
    back = tier.read_back(list(enumerate(hids)), nbkt=2)
    for seg_b, seg_u, keys in zip(back, upd, tier._page_keys):
        for key in keys:
            np.testing.assert_array_equal(
                np.asarray(seg_b[key]), np.asarray(seg_u[key]), err_msg=key)


# ---------------------------------------------------------------------------
# Pinned dct bitwise parity with the pre-refactor tree
# ---------------------------------------------------------------------------

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]
PYRAMID = "0-1:keep=8,2-:keep=4"

# captured at commit 29d5032 (pre-refactor) — single-device and 4x1 mesh
# produce identical streams there, so one literal pins both paths here
BASELINE = {
    "uniform": {
        "tokens": [[206, 84, 84],
                   [118, 118, 118, 177, 177, 96, 118],
                   [167, 102, 107, 121, 34],
                   [49, 100, 60, 255, 159, 78, 17, 56, 74],
                   [20, 206, 34, 64],
                   [49, 80, 4, 49, 232, 49],
                   [69, 39, 49, 118, 118, 118, 118, 69],
                   [3, 101, 39, 232, 51]],
        "kv_pool_bytes": 47232, "page_bytes": 640, "pool_pages": 48,
    },
    "pyramid": {
        "tokens": [[206, 84, 84],
                   [118, 22, 235, 59, 79, 59, 79],
                   [167, 34, 194, 228, 34],
                   [49, 49, 253, 253, 253, 253, 178, 91, 253],
                   [20, 206, 34, 64],
                   [49, 49, 249, 193, 253, 49],
                   [69, 231, 77, 69, 77, 79, 79, 34],
                   [3, 84, 84, 185, 219]],
        "kv_pool_bytes": 84096, "page_bytes": 1408, "pool_pages": 48,
    },
}


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i,
                      prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


def _baseline_serve(api, params, plan, mesh=None):
    sc = E.ServeConfig(max_seq=64, kv_compress=True, plan=plan,
                       codec_backend="reference", mesh=mesh, pool_pages=48)
    eng = E.Engine(api, params, sc, batch=4)
    done = eng.generate(_requests())
    assert all(r.done for r in done)
    return [list(map(int, r.out_tokens)) for r in done], eng.kv_pool_stats()


@pytest.mark.parametrize("plan_name,plan",
                         [("uniform", 4), ("pyramid", PYRAMID)])
def test_dct_bitwise_parity_pinned(lm, plan_name, plan):
    """The refactored dct path reproduces the pre-refactor greedy stream and
    pool accounting EXACTLY — the family seam is pure layout."""
    api, params = lm
    toks, stats = _baseline_serve(api, params, plan)
    want = BASELINE[plan_name]
    assert toks == want["tokens"]
    assert int(stats["kv_pool_bytes"]) == want["kv_pool_bytes"]
    assert int(stats["page_bytes"]) == want["page_bytes"]
    assert int(stats["pool_pages"]) == want["pool_pages"]


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
@pytest.mark.parametrize("plan_name,plan",
                         [("uniform", 4), ("pyramid", PYRAMID)])
def test_dct_bitwise_parity_pinned_mesh(lm, plan_name, plan):
    """Same pinned literals on a 4x1 serve mesh: the generic plane-name
    sharding rules place the refactored pool exactly like the old
    hard-coded packed/scale rules did."""
    from repro.parallel import mesh as mesh_lib

    api, params = lm
    toks, stats = _baseline_serve(api, params, plan,
                                  mesh=mesh_lib.make_serve_mesh("4x1"))
    want = BASELINE[plan_name]
    assert toks == want["tokens"]
    assert int(stats["kv_pool_bytes"]) == want["kv_pool_bytes"]


# ---------------------------------------------------------------------------
# Mixed-codec plans serve end to end
# ---------------------------------------------------------------------------

MIXED = "0-1:keep=6,2-:keep=4+codec=bitplane"


@pytest.mark.parametrize("plan", [MIXED, "0-:keep=4+codec=asc"],
                         ids=["mixed_dct_bitplane", "uniform_asc"])
def test_non_dct_plans_serve_paged_e2e(lm, plan):
    """Non-default families thread plan -> pool -> engine: requests complete
    through the paged pool, the pool reports both analytic and measured
    bytes, and measured never exceeds the analytic allocation."""
    api, params = lm
    sc = E.ServeConfig(max_seq=64, kv_compress=True, plan=plan,
                       codec_backend="reference", pool_pages=48)
    eng = E.Engine(api, params, sc, batch=4)
    done = eng.generate(_requests())
    assert all(r.done for r in done)
    assert [len(r.out_tokens) for r in done] == MAX_NEWS
    stats = eng.kv_pool_stats()
    assert stats["measured_kv_bytes"] > 0
    # the pool served 8 short requests through 48 pages: the data-dependent
    # footprint must sit well inside the analytic allocation
    assert stats["measured_kv_bytes"] <= stats["kv_pool_bytes"]


def test_mixed_plan_greedy_matches_uniform_prefix_layers(lm):
    """Sanity on semantics, not bits: a mixed plan with bitplane (lossless
    repack of the same quantized blocks) on layers 2+ must produce exactly
    the tokens of the all-dct plan with the same keeps — bitplane changes
    storage, never values."""
    api, params = lm
    base, _ = _baseline_serve(api, params, "0-1:keep=6,2-:keep=4")
    got, _ = _baseline_serve(api, params, MIXED)
    assert got == base
