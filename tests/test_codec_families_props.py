"""Property tests for the codec-family contract (hypothesis).

Three invariants hold for EVERY registered family on arbitrary quantized
tiles, not just the fixtures the unit tests pick:

  * pack -> unpack reproduces the int8 coefficient blocks bitwise;
  * analytic_tile_bytes upper-bounds measured_tile_bits (the plan/pool can
    budget analytically and never under-allocate what a tile stored);
  * the bitplane family's stored per-tile length equals the numpy
    `core.encode.rle_codec_bits` reference exactly (one RLE accounting).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.codec import families as families_lib
from repro.core import encode as encode_lib

FAMILIES = ["dct", "bitplane", "asc"]


@st.composite
def quantized_tiles(draw):
    """(q int8 (n, nh, k, k), scale f32 (n, nh), keep) with adversarial zero
    structure: dense, empty, and sparse tiles all appear."""
    keep = draw(st.integers(1, 8))
    n = draw(st.integers(1, 4))
    nh = draw(st.integers(1, 2))
    zero_frac = draw(st.sampled_from([0.0, 0.3, 0.8, 1.0]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, (n, nh, keep, keep)).astype(np.int8)
    q = np.where(rng.random(q.shape) < zero_frac, 0, q)
    scale = (rng.random((n, nh)).astype(np.float32) * 4.0).astype(np.float32)
    scale = np.where(np.any(q != 0, axis=(-1, -2)), scale, 0.0)
    return jnp.asarray(q), jnp.asarray(scale), keep


@pytest.mark.parametrize("name", FAMILIES)
@settings(max_examples=25, deadline=None)
@given(data=quantized_tiles())
def test_roundtrip_exact(name, data):
    q, scale, keep = data
    fam = families_lib.get_family(name)
    q2, _ = fam.unpack(fam.pack(q, scale, keep), keep)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


@pytest.mark.parametrize("name", FAMILIES)
@settings(max_examples=25, deadline=None)
@given(data=quantized_tiles())
def test_analytic_upper_bounds_measured(name, data):
    q, _, keep = data
    fam = families_lib.get_family(name)
    bits = np.asarray(fam.measured_tile_bits(q))
    assert (bits <= 8 * fam.analytic_tile_bytes(keep)).all()


@settings(max_examples=25, deadline=None)
@given(data=quantized_tiles())
def test_bitplane_blen_is_the_rle_reference(data):
    q, scale, keep = data
    fam = families_lib.get_family("bitplane")
    blen = np.asarray(fam.pack(q, scale, keep)["blen"])
    qn = np.asarray(q)
    for idx in np.ndindex(qn.shape[:-2]):
        want = encode_lib.rle_codec_bits(qn[idx].reshape(-1),
                                         fam.VALUE_BITS, fam.RUN_BITS)
        assert int(blen[idx]) == want
