"""DCT core tests: orthogonality, scipy oracle, fast-path equivalence, roundtrip.

Float64 oracle checks run in NumPy against the float64 DCT matrix directly —
we deliberately do NOT flip jax_enable_x64, which would leak into every other
test module in the pytest process (conv dtype mismatches etc.).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.fft
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dct as dct_lib


def _dct2_np(x: np.ndarray) -> np.ndarray:
    c = dct_lib._dct_matrix_np(8)
    return c @ x @ c.T


def test_dct_matrix_orthonormal():
    c = dct_lib._dct_matrix_np(8)
    np.testing.assert_allclose(c @ c.T, np.eye(8), atol=1e-12)


def test_dct_matches_scipy_f64():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 8))
    ref = scipy.fft.dctn(x, type=2, norm="ortho")
    np.testing.assert_allclose(_dct2_np(x), ref, atol=1e-10)


def test_idct_matches_scipy_f64():
    rng = np.random.default_rng(1)
    z = rng.standard_normal((8, 8))
    c = dct_lib._dct_matrix_np(8)
    ref = scipy.fft.idctn(z, type=2, norm="ortho")
    np.testing.assert_allclose(c.T @ z @ c, ref, atol=1e-10)


def test_jax_dct_matches_scipy_f32():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 8)).astype(np.float32)
    ours = np.asarray(dct_lib.dct2_blocks(jnp.asarray(x)))
    ref = scipy.fft.dctn(np.float64(x), type=2, norm="ortho")
    np.testing.assert_allclose(ours, ref, atol=1e-4)


def test_jax_idct_matches_scipy_f32():
    rng = np.random.default_rng(1)
    z = rng.standard_normal((8, 8)).astype(np.float32)
    ours = np.asarray(dct_lib.idct2_blocks(jnp.asarray(z)))
    ref = scipy.fft.idctn(np.float64(z), type=2, norm="ortho")
    np.testing.assert_allclose(ours, ref, atol=1e-4)


def test_fast_gong_equals_dense():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((5, 3, 8, 8)).astype(np.float32))
    dense = dct_lib.dct2_blocks(x)
    fast = dct_lib.dct2_blocks_fast(x)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(dense), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(1, 40),
    w=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_pad_crop_roundtrip(h, w, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((h, w)).astype(np.float32))
    padded, _ = dct_lib.pad_to_block(x)
    assert padded.shape[-1] % 8 == 0 and padded.shape[-2] % 8 == 0
    back = dct_lib.crop_from_block(padded, (h, w))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(
    nh=st.integers(1, 4),
    nw=st.integers(1, 4),
    lead=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_dct_idct_identity(nh, nw, lead, seed):
    """Lossless DCT->IDCT on exact block multiples (property: unitary)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((lead, nh * 8, nw * 8)).astype(np.float32))
    z = dct_lib.dct2(x)
    back = dct_lib.idct2(z)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-5)


def test_energy_preservation():
    """Parseval: unitary transform preserves total energy."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 24)).astype(np.float32))
    z = dct_lib.dct2(x)
    np.testing.assert_allclose(
        float(jnp.sum(x**2)), float(jnp.sum(z**2)), rtol=1e-5
    )


def test_dc_component():
    """Constant block -> all energy in the DC coefficient (8x mean)."""
    x = jnp.full((8, 8), 3.0)
    z = np.asarray(dct_lib.dct2_blocks(x))
    assert abs(z[0, 0] - 8 * 3.0) < 1e-5
    assert np.abs(z.reshape(-1)[1:]).max() < 1e-5
