"""Decode-bucket ladder + multi-page tiled paged attend (PR 7).

Pins the PR's acceptance criteria:
  * tile-width invariance — greedy decode tokens are identical across
    pages-per-tile G in {1, 2, 4, 8} (one-page G=1 is the old kernel's
    schedule) and across the fused-pallas / reference backends, dense and
    paged pools, uniform and pyramid plans;
  * the decode ladder is output-exact — bucketed engines (decode_buckets
    auto) produce bitwise the single-full-capacity-bucket engine's tokens
    (the sliced table entries can only name blocks the flushed-watermark
    mask discards anyway), single-device and 4x1 mesh;
  * zero jit traces under traffic once the ladder is warm: AOT warmup
    compiles exactly len(decode_ladder.buckets) decode steps and a
    multi-bucket workload compiles nothing more;
  * DecodeLadder bucket selection and validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as KV
from repro.models import api as model_api
from repro.serve import engine as E
from repro.serve import pipeline as pl

PLENS = [5, 11, 17, 8]
MAX_NEWS = [6, 5, 4, 7]
PYRAMID = "0-1:keep=8,2-:keep=4"


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=4, seed=7):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i,
                      prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


# ---------------------------------------------------------------------------
# Ladder unit (no model)
# ---------------------------------------------------------------------------

def test_decode_ladder_build_and_bucket_for():
    lad = pl.DecodeLadder.build(64)
    assert lad.buckets == (8, 16, 32, 64)
    assert lad.bucket_for(0) == 8
    assert lad.bucket_for(16) == 16
    assert lad.bucket_for(17) == 32
    assert lad.bucket_for(64) == 64
    with pytest.raises(ValueError, match="ladder"):
        lad.bucket_for(65)
    # off = one full-capacity bucket (the pre-ladder decode step)
    assert pl.DecodeLadder.build(64, False).buckets == (64,)
    assert pl.DecodeLadder.build(64, "off").buckets == (64,)
    # an explicit ladder is completed to max_seq: every legal watermark
    # must have a covering bucket
    assert pl.DecodeLadder.build(64, (16,)).buckets == (16, 64)
    assert pl.DecodeLadder.build(64, (64, 16)).buckets == (16, 64)
    with pytest.raises(ValueError, match="multiple"):
        pl.DecodeLadder.build(64, (12,))
    with pytest.raises(ValueError, match="max_seq"):
        pl.DecodeLadder.build(64, (128,))
    with pytest.raises(ValueError, match="empty"):
        pl.DecodeLadder.build(64, ())


# ---------------------------------------------------------------------------
# Kernel-level tile-width invariance (fast: one attend, no engine)
# ---------------------------------------------------------------------------

def test_attend_paged_tile_width_invariance():
    """One paged attend over a scrambled 13-page pool: the fused kernel's
    output is G-invariant (same flash merge, different tile schedule) and
    matches the reference gather; trailing unmapped table entries sliced
    off by table_view change nothing."""
    from repro.kernels.fused_attend import ops as fa_ops

    b, hkv, n_rep, hd, keep, n_pages = 2, 2, 2, 16, 4, 13
    nh, depth = hd // 8, 29
    rng = np.random.default_rng(3)
    cache = {
        "packed_k": jnp.asarray(rng.integers(-8, 8, (n_pages, hkv, nh, keep, keep), np.int8)),
        "scale_k": jnp.asarray(rng.uniform(0.5, 2, (n_pages, hkv, nh)).astype(np.float32)),
        "packed_v": jnp.asarray(rng.integers(-8, 8, (n_pages, hkv, nh, keep, keep), np.int8)),
        "scale_v": jnp.asarray(rng.uniform(0.5, 2, (n_pages, hkv, nh)).astype(np.float32)),
        "tail_k": jnp.asarray(rng.standard_normal((b, 8, hkv, hd)).astype(np.float32)),
        "tail_v": jnp.asarray(rng.standard_normal((b, 8, hkv, hd)).astype(np.float32)),
    }
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * n_rep, hd)).astype(np.float32))
    pos = jnp.asarray([depth - 1, 14], jnp.int32)  # per-row watermarks
    table = np.zeros((b, 8), np.int32)  # 64-token capacity, partly occupied
    perm = rng.permutation(n_pages)
    for i in range(b):
        for j in range(int(pos[i]) // 8):
            table[i, j] = int(perm[(i * 4 + j) % n_pages])
    table = jnp.asarray(table)

    ref = KV.attend_compressed(q, cache, pos, keep, kv_block=16,
                               block_table=table)
    outs = [fa_ops.attend_with_tail(q, cache, pos, block_table=table,
                                    pages_per_tile=g)
            for g in (1, 2, 4, 8)]
    for g, out in zip((1, 2, 4, 8), outs):
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, err_msg=f"G={g}")
        # G changes the flash-merge tile schedule only: bit-level drift
        # between widths stays at float32 rounding noise
        np.testing.assert_allclose(np.asarray(out), np.asarray(outs[0]),
                                   atol=1e-5, err_msg=f"G={g} vs G=1")
    # the decode-ladder slice is exact: drop trailing entries past every
    # row's watermark (max pos 28 -> 3 flushed pages + tail)
    sliced = KV.table_view(table, 4)
    out_sl = fa_ops.attend_with_tail(q, cache, pos, block_table=sliced,
                                     pages_per_tile=2)
    np.testing.assert_array_equal(np.asarray(out_sl), np.asarray(outs[1]))


# ---------------------------------------------------------------------------
# Engine: ladder on == ladder off, bitwise (the exactness contract)
# ---------------------------------------------------------------------------

def test_ladder_on_off_parity_single_device(lm):
    api, params = lm
    kw = dict(max_seq=32, kv_compress=True, kv_keep=8,
              codec_backend="reference", pool_pages=16)
    on = E.Engine(api, params, E.ServeConfig(**kw), batch=2)
    off = E.Engine(api, params, E.ServeConfig(**kw, decode_buckets=False),
                   batch=2)
    assert on.decode_ladder.buckets == (8, 16, 32)
    assert off.decode_ladder.buckets == (32,)
    a = on.generate(_requests())
    b = off.generate(_requests())
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    # the ladder actually engaged: mean dispatched bucket < full capacity
    assert 0 < on.stats["decode_bucket_tokens"] < 32 * on.stats["steps"]
    assert off.stats["decode_bucket_tokens"] == 32 * off.stats["steps"]


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")
def test_ladder_parity_on_mesh(lm):
    """4x1 mesh: the bucketed decode jits share the full-capacity step's
    shardings, so ladder on == ladder off == single-device, bitwise."""
    from repro.parallel import mesh as mesh_lib

    api, params = lm
    kw = dict(max_seq=32, kv_compress=True, kv_keep=8,
              codec_backend="reference", pool_pages=32)
    base = E.Engine(api, params, E.ServeConfig(**kw, decode_buckets=False),
                    batch=4).generate(_requests())
    eng = E.Engine(api, params,
                   E.ServeConfig(**kw, mesh=mesh_lib.make_serve_mesh("4x1")),
                   batch=4)
    got = eng.generate(_requests())
    assert [r.out_tokens for r in got] == [r.out_tokens for r in base]
    assert eng.stats["decode_bucket_tokens"] < 32 * eng.stats["steps"]


# ---------------------------------------------------------------------------
# Engine: greedy tokens are G-invariant through the fused kernel
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_engine_tile_width_invariance_pallas(lm, plan):
    """Full serve traffic through the fused paged kernel (interpret on CPU)
    at every tile width: greedy tokens must be identical across G — G=1 is
    the old one-page schedule — and match the dense-pool engine on the same
    kernel backend."""
    api, params = lm
    kw = dict(max_seq=32, kv_compress=True, plan=plan, codec_backend="pallas")
    dense = E.Engine(api, params, E.ServeConfig(**kw), batch=2) \
        .generate(_requests())
    toks = {}
    for g in (1, 2, 4, 8):
        eng = E.Engine(api, params,
                       E.ServeConfig(**kw, pool_pages=16,
                                     decode_tile_pages=g), batch=2)
        toks[g] = [r.out_tokens for r in eng.generate(_requests())]
    for g in (2, 4, 8):
        assert toks[g] == toks[1], f"G={g} diverged from one-page schedule"
    assert toks[1] == [r.out_tokens for r in dense]


# ---------------------------------------------------------------------------
# Zero traces under traffic with a warmed ladder
# ---------------------------------------------------------------------------

def test_warmed_ladder_compiles_once_per_bucket(lm):
    api, params = lm
    sc = E.ServeConfig(max_seq=32, kv_compress=True, kv_keep=8,
                       codec_backend="reference", pool_pages=16,
                       aot_warmup=True)
    eng = E.Engine(api, params, sc, batch=2)
    snap = eng.trace_counts.snapshot()
    assert eng.decode_ladder.buckets == (8, 16, 32)
    # one decode trace per ladder bucket, all ahead of traffic
    assert snap["decode"] == len(eng.decode_ladder.buckets)
    # traffic spanning several buckets (deepest context reaches 27 tokens)
    rng = np.random.default_rng(2)
    reqs = [E.Request(uid=i, prompt=rng.integers(0, 200, p).astype(np.int32),
                      max_new=n)
            for i, (p, n) in enumerate([(4, 3), (12, 8), (19, 8)])]
    done = eng.generate(reqs)
    assert all(r.done for r in done)
    assert eng.trace_counts.delta(snap) == {}  # zero compiles under traffic
    buckets_hit = eng.stats["decode_bucket_tokens"]
    assert 0 < buckets_hit < 32 * eng.stats["steps"]  # ladder engaged
