"""Elastic-restart integration: checkpoint on one mesh geometry, resume on
another (different dp size), and continue training — state and data stream
both survive the re-shard. Runs on whatever devices exist (1 on CPU CI; the
re-shard path still executes through make_array_from_callback)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import store
from repro.parallel import mesh as mesh_lib
from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.optim.adamw import AdamWConfig
from repro.train import step as train_step


@pytest.fixture(scope="module")
def setup():
    api = model_api.build_reduced("qwen2_0_5b")
    ts = TokenStream(vocab_size=api.cfg.vocab_size, seq_len=32, global_batch=8)
    tc = train_step.TrainConfig(
        microbatches=2, remat="full",
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40),
    )
    return api, ts, tc


def test_resume_with_new_mesh_geometry(tmp_path, setup):
    api, ts, tc = setup
    root = str(tmp_path / "ck")

    # phase 1: "old fleet"
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    state = train_step.init_train_state(api, tc)
    with mesh_lib.use_mesh(mesh1):
        step1 = jax.jit(train_step.make_train_step(api, mesh1, tc))
        for i in range(4):
            b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
            state, m = step1(state, b)
    store.save(root, 4, state)

    # phase 2: "replacement fleet" with a different (degenerate) geometry +
    # restore re-sharded onto the new mesh via explicit shardings
    mesh2 = jax.make_mesh((1,), ("data",))
    like = jax.eval_shape(lambda: train_step.init_train_state(api, tc))
    sspec = train_step.state_specs(like, mesh2, tc)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh2, s), sspec)
    restored, at = store.restore(root, like, shardings=shardings)
    assert at == 4
    assert int(restored["step"]) == 4

    losses = []
    with mesh_lib.use_mesh(mesh2):
        step2 = jax.jit(train_step.make_train_step(api, mesh2, tc))
        for i in range(4, 12):
            b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
            restored, m = step2(restored, b)
            losses.append(float(m["loss"]))
    assert int(restored["step"]) == 12
    assert all(np.isfinite(losses))
    # training continues to improve post-reshard
    assert losses[-1] < losses[0] + 0.2


def test_data_stream_identical_across_dp_change(setup):
    """The global token stream at step t is the union of shards for ANY dp."""
    _, ts, _ = setup
    full = ts.batch(7, 0, 1)["tokens"]
    for dp in (2, 4, 8):
        parts = np.concatenate(
            [ts.batch(7, i, dp)["tokens"] for i in range(dp)], axis=0)
        assert parts.shape == full.shape
        # per-shard streams are deterministic and disjoint by construction
        again = np.concatenate(
            [ts.batch(7, i, dp)["tokens"] for i in range(dp)], axis=0)
        np.testing.assert_array_equal(parts, again)
