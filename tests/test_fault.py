"""Fault-tolerance runtime tests: preemption, stragglers, heartbeat,
checkpoint retention/commit protocol, elastic data replay."""
import os
import signal
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import store
from repro.data.synthetic import TokenStream
from repro.runtime import fault


def test_preemption_guard_triggers_save(tmp_path):
    saves = []

    def step_fn(state, batch):
        if state == 3:  # simulate SIGTERM mid-run
            os.kill(os.getpid(), signal.SIGTERM)
        return state + 1, {}

    state, last, reason = fault.train_loop(
        step_fn, 0, lambda i: i,
        start_step=0, num_steps=100, save_every=50,
        save_fn=lambda s, st: saves.append(s),
    )
    assert reason == "preempted"
    assert last == 4           # stopped right after the signalled step
    assert saves == [4]        # checkpointed immediately, lost nothing


def test_train_loop_completes_and_saves(tmp_path):
    saves = []
    state, last, reason = fault.train_loop(
        lambda s, b: (s + 1, {}), 0, lambda i: i,
        start_step=0, num_steps=7, save_every=3,
        save_fn=lambda s, st: saves.append(s),
    )
    assert reason == "done" and last == 7
    assert saves == [3, 6, 7]  # periodic + final partial


def test_straggler_monitor():
    mon = fault.StragglerMonitor(window=8, threshold=1.5)
    for step in range(8):
        for host in range(4):
            mon.record(host, 1.0 if host != 2 else 2.5)
    assert mon.stragglers() == [2]
    assert mon.mitigation(2) != "none"
    assert mon.mitigation(0) == "none"


def test_heartbeat(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = fault.Heartbeat(path, interval_s=0.05).start()
    time.sleep(0.12)
    hb.stop()
    assert fault.Heartbeat.age(path) < 5.0
    assert fault.Heartbeat.age(str(tmp_path / "missing.json")) == float("inf")


def test_commit_marker_protocol(tmp_path):
    """Uncommitted (crashed mid-write) checkpoints are invisible."""
    root = str(tmp_path / "ck")
    state = {"w": jnp.arange(8.0)}
    store.save(root, 1, state)
    store.save(root, 2, state)
    # simulate a crash: step_3 dir exists but no commit marker
    os.makedirs(os.path.join(root, "step_000000003"))
    assert store.committed_steps(root) == [1, 2]
    assert store.latest_step(root) == 2


def test_retention(tmp_path):
    root = str(tmp_path / "ck")
    state = {"w": jnp.arange(4.0)}
    for s in range(1, 6):
        store.save(root, s, state, keep=2)
    assert store.committed_steps(root) == [4, 5]


def test_async_save(tmp_path):
    root = str(tmp_path / "ck")
    state = {"w": jnp.arange(16.0)}
    t = store.save_async(root, 7, state)
    store.wait_pending()
    restored, at = store.restore(root, state)
    assert at == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(16.0))


def test_elastic_data_replay():
    """Changing dp size across a restart must not duplicate/skip tokens
    within a step: the union of shards equals the global batch either way."""
    ts = TokenStream(vocab_size=1000, seq_len=16, global_batch=8)
    full = ts.batch(5, shard=0, num_shards=1)["tokens"]
    for dp in (2, 4):
        parts = [ts.batch(5, shard=i, num_shards=dp)["tokens"] for i in range(dp)]
        merged = np.concatenate(parts, axis=0)
        assert merged.shape == full.shape
        # deterministic per (step, shard, num_shards); shards are disjoint rows
        assert len({p.tobytes() for p in parts}) == dp
    plan = fault.ElasticPlan(resume_step=5, old_dp=2, new_dp=4)
    assert plan.shard_for(6) == (2, 4)
