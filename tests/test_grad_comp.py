"""GradCompress unit + property tests (core/grad_comp.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import grad_comp as GC


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 6),
    cols=st.integers(1, 6),
    keep=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
    mode=st.sampled_from(["topk", "corner"]),
)
def test_leaf_roundtrip(rows, cols, keep, seed, mode):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((rows * 8, cols * 8)).astype(np.float32))
    q, idx, s = GC.compress_leaf(g, keep, mode)
    back = GC.decompress_leaf(q, idx, s, g.shape)
    err = float(jnp.linalg.norm(back - g) / (jnp.linalg.norm(g) + 1e-9))
    assert err < 1.05
    if keep == 8:
        assert err < 0.05


def _ef_run(g_true, keep, mode, steps=40):
    residual = jnp.zeros_like(g_true)
    received = []
    for _ in range(steps):
        g_fb = g_true + residual
        q, idx, s = GC.compress_leaf(g_fb, keep, mode)
        approx = GC.decompress_leaf(q, idx, s, g_true.shape)
        residual = g_fb - approx
        received.append(approx)
    mean_received = jnp.mean(jnp.stack(received), axis=0)
    err = float(jnp.linalg.norm(mean_received - g_true) / jnp.linalg.norm(g_true))
    return err, float(jnp.linalg.norm(residual))


def test_error_feedback_topk_converges_corner_diverges():
    """EF needs a CONTRACTIVE compressor. Magnitude top-k contracts (the
    mean received gradient converges to the truth); the paper's fixed-corner
    projection is idempotent — its residual grows linearly and the mean never
    improves. This pins the refuted-hypothesis log in EXPERIMENTS.md §Perf."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    q, idx, s = GC.compress_leaf(g_true, 3, "topk")
    one = GC.decompress_leaf(q, idx, s, g_true.shape)
    one_err = float(jnp.linalg.norm(one - g_true) / jnp.linalg.norm(g_true))

    err_topk, resid_topk = _ef_run(g_true, 3, "topk")
    err_corner, resid_corner = _ef_run(g_true, 3, "corner")

    assert err_topk < 0.35 * one_err, (err_topk, one_err)   # ~10x better
    assert err_corner > 0.8 * one_err          # never improves
    assert resid_corner > 10 * resid_topk      # linear blow-up (measured 13x)


def test_exchange_compressed_under_shard_map():
    """2-pod exchange: both pods receive the mean of the per-pod grads.

    Goes through the parallel.mesh.shard_map compat shim so it runs on the
    pinned 0.4.x (jax.experimental.shard_map) and newer jax alike — the CI
    multidevice job exercises this case under 4 forced host devices."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (run under XLA_FLAGS device_count)")
    from repro.parallel import mesh as mesh_lib

    mesh = jax.make_mesh((2,), ("pod",))
    grads = {"w": jnp.stack([jnp.ones((16, 16)), 3 * jnp.ones((16, 16))])}
    residual = {"w": jnp.zeros((16, 16))}
    cfg = GC.GradCompressConfig(keep=8)

    def f(g, r):
        out, new_r = GC.exchange_compressed(g, r, cfg, axis="pod")
        return out, new_r

    from jax.sharding import PartitionSpec as P
    g_local = {"w": grads["w"].reshape(32, 16)}  # (2*16, 16) sharded over pod
    fn = mesh_lib.shard_map(
        lambda g, r: f({"w": g["w"]}, r),
        mesh=mesh, in_specs=({"w": P("pod")}, {"w": P()}),
        out_specs=({"w": P("pod")}, {"w": P()}), axis_names={"pod"},
        check_vma=False,
    )
    out, _ = fn(g_local, residual)
    # mean of (1, 3) = 2 everywhere (up to int8 quant)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0, atol=0.05)


def test_small_leaves_bypass():
    grads = {"bias": jnp.ones((7,)), "big": jnp.ones((64, 64))}
    res = GC.init_residual(grads)
    assert res["bias"].shape == ()       # placeholder
    assert res["big"].shape == (64, 64)


def test_wire_bytes_accounting():
    params = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((7,))}
    wb = GC.wire_bytes(params, GC.GradCompressConfig(keep=5))
    # topk: (2*25+4)/64 bytes per tile of 64 f32 = ~0.21 + the raw bias
    assert wb["ratio"] < 0.25
    wb_corner = GC.wire_bytes(params, GC.GradCompressConfig(keep=5, mode="corner"))
    assert wb_corner["ratio"] < 0.13
