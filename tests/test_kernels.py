"""Per-kernel allclose tests: the `pallas` codec backend (interpret mode on
CPU) vs the pure-jnp oracles in kernels/*/ref.py.

All kernel access goes through `repro.codec` — the backend registry owns
interpret-mode selection and plane folding; tests pick the backend by name.
Sweeps shapes/dtypes per the kernel CI contract; hypothesis drives random
shape/seed combinations on top of the fixed sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import codec
from repro.core import compressor
from repro.core import dct as dct_lib
from repro.kernels.dct8x8 import ref as dct_ref
from repro.kernels.fused_compress import ref as fc_ref
from repro.kernels.quant_pack import ref as qp_ref

SHAPES = [(8, 8), (8, 128), (64, 64), (128, 128), (40, 264), (256, 136)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _to_blocks(packed, scale, keep):
    """Plane-packed ref output (R*k/8, C*k/8) -> codec blocks (nh, nw, k, k)."""
    nh, nw = scale.shape
    return jnp.swapaxes(packed.reshape(nh, keep, nw, keep), 1, 2)


# ------------------------------ dct8x8 -------------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dct_kernel_matches_ref(shape, dtype):
    x = _rand(shape, dtype, 0)
    got = codec.dct2(x, backend="pallas")
    want = dct_ref.dct2_plane(x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_idct_kernel_matches_ref(shape):
    z = _rand(shape, jnp.float32, 1)
    got = codec.idct2(z, backend="pallas")
    want = dct_ref.idct2_plane(z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_dct_kernel_batched():
    x = _rand((3, 16, 32), jnp.float32, 2)
    got = codec.dct2(x, backend="pallas")
    want = jnp.stack([dct_ref.dct2_plane(x[i]) for i in range(3)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    nh=st.integers(1, 20),
    nw=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_dct_idct_kernel_roundtrip(nh, nw, seed):
    x = _rand((nh * 8, nw * 8), jnp.float32, seed)
    z = codec.dct2(x, backend="pallas")
    back = codec.idct2(z, backend="pallas")
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


# --------------------------- fused_compress --------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("keep", [2, 4, 6, 8])
def test_fused_compress_matches_ref(shape, keep):
    x = _rand(shape, jnp.float32, 3)
    padded, _ = dct_lib.pad_to_block(x)
    q, scale = codec.compress_blocks(padded, keep, backend="pallas")
    rp, rs = fc_ref.compress_plane(padded, keep)
    np.testing.assert_allclose(np.asarray(scale), np.asarray(rs), rtol=1e-6)
    # int8 codes may differ by 1 ulp at exact rounding ties — allow off-by-one
    diff = np.abs(
        np.asarray(q, np.int32) - np.asarray(_to_blocks(rp, rs, keep), np.int32)
    )
    assert diff.max() <= 1
    assert (diff > 0).mean() < 0.01


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("keep", [2, 4, 8])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_decompress_matches_ref(shape, keep, dtype):
    x = _rand(shape, jnp.float32, 4)
    padded, _ = dct_lib.pad_to_block(x)
    packed, scale = fc_ref.compress_plane(padded, keep)
    got = codec.decompress_blocks(
        _to_blocks(packed, scale, keep), scale, out_dtype=dtype, backend="pallas"
    )
    want = fc_ref.decompress_plane(packed, scale, keep, dtype=dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        atol=1e-5 if dtype == jnp.float32 else 5e-2,
    )


def test_fused_kernel_consistent_with_compressor():
    """Kernel path and reference TruncatedCompressed path reconstruct alike."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    keep = 4
    y_kernel = codec.roundtrip(x, keep, backend="pallas")
    y_ref = compressor.roundtrip_truncated(x, keep, backend="reference")
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_ref), atol=2e-2
    )


def test_fused_compress_batched_shapes():
    x = _rand((2, 5, 16, 32), jnp.float32, 6)
    q, scale = codec.compress_blocks(x, 4, backend="pallas")
    assert q.shape == (2, 5, 2, 4, 4, 4) and q.dtype == jnp.int8
    assert scale.shape == (2, 5, 2, 4)
    y = codec.decompress_blocks(q, scale, backend="pallas")
    assert y.shape == x.shape


@settings(max_examples=10, deadline=None)
@given(
    nh=st.integers(1, 8),
    nw=st.integers(1, 8),
    keep=st.sampled_from([2, 3, 4, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_roundtrip_error_bound(nh, nw, keep, seed):
    """keep=8 roundtrip == int8 quantization error only; k<8 bounded energy loss."""
    x = _rand((nh * 8, nw * 8), jnp.float32, seed)
    y = codec.roundtrip(x, keep, backend="pallas")
    assert np.all(np.isfinite(np.asarray(y)))
    if keep == 8:
        # |err| <= scale/2 per coefficient; scale <= max|coef|/127
        assert float(jnp.max(jnp.abs(y - x))) < 0.2 * float(jnp.max(jnp.abs(x)))


# ----------------------------- quant_pack ----------------------------------

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("level", [0, 1, 2, 3])
def test_quant_pack_matches_ref(shape, level):
    x = _rand(shape, jnp.float32, 7) * 10.0
    padded, _ = dct_lib.pad_to_block(x)
    fmin = float(jnp.min(padded))
    fmax = float(jnp.max(padded))
    q2, idx, nnz = codec.quant_pack(padded, fmin, fmax, level=level, backend="pallas")
    rq2, ridx, rnnz = qp_ref.quant_pack_plane(padded, fmin, fmax, level)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(rq2))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    assert int(nnz) == int(rnnz)


@pytest.mark.parametrize("bits", [4, 8, 12])
def test_quant_pack_bits_sweep(bits):
    x = _rand((32, 64), jnp.float32, 8) * 3.0
    fmin = float(jnp.min(x))
    fmax = float(jnp.max(x))
    q2, idx, nnz = codec.quant_pack(x, fmin, fmax, level=1, bits=bits, backend="pallas")
    assert int(nnz) == int(np.count_nonzero(np.asarray(q2)))
    assert int(nnz) <= x.size


# ---------------------------------------------------------------------------
# fused_attend: decompress+attend kernel vs pure-jnp oracle
# ---------------------------------------------------------------------------

from repro.core import kv_cache as _kvc
from repro.kernels.fused_attend import ops as fa_ops
from repro.kernels.fused_attend.kernel import attend_compressed_plane
from repro.kernels.fused_attend.ref import attend_compressed_plane_ref


@pytest.mark.parametrize("s,hd,keep,h", [
    (32, 16, 4, 2), (64, 16, 8, 4), (64, 32, 2, 8), (128, 8, 6, 1),
])
def test_fused_attend_matches_ref(s, hd, keep, h):
    rng = np.random.default_rng(s + hd + keep)
    k = rng.standard_normal((s, hd)).astype(np.float32)
    v = rng.standard_normal((s, hd)).astype(np.float32)
    pk, sk = _kvc.compress_kv_blocks(jnp.asarray(k)[None], keep)
    pv, sv = _kvc.compress_kv_blocks(jnp.asarray(v)[None], keep)
    q = jnp.asarray(rng.standard_normal((h, hd)).astype(np.float32))
    pos = jnp.int32(s - 3)
    acc, m, l = attend_compressed_plane(pk[0], sk[0], pv[0], sv[0], q, pos,
                                        tile_s=16)
    acc_r, m_r, l_r = attend_compressed_plane_ref(pk[0], sk[0], pv[0], sv[0], q, pos)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(acc_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r), rtol=1e-5)


def test_fused_attend_with_tail_matches_core():
    from repro.configs.base import get_config

    cfg = get_config("yi_6b").reduced()
    b, max_seq, keep = 2, 64, 6
    hkv, h, hd = cfg.n_kv_heads, cfg.n_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(5)
    cache = _kvc.init_compressed_cache(cfg, b, max_seq, keep=keep, dtype=jnp.float32)
    lc = {"packed_k": cache.packed_k[0], "scale_k": cache.scale_k[0],
          "packed_v": cache.packed_v[0], "scale_v": cache.scale_v[0],
          "tail_k": cache.tail_k[0], "tail_v": cache.tail_v[0]}
    ks = jnp.asarray(rng.standard_normal((b, 30, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, 30, hkv, hd)).astype(np.float32))
    for t in range(30):
        lc = _kvc.update_layer(lc, ks[:, t:t+1], vs[:, t:t+1], jnp.int32(t), keep)
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    # interpret mode auto-resolves (CPU here) — no caller-side selection
    o_kernel = fa_ops.attend_with_tail(q, lc, jnp.int32(29), tile_s=32)
    o_core = _kvc.attend_compressed(q, lc, jnp.int32(29), keep, kv_block=32)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_core), atol=1e-4)
