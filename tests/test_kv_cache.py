"""KVCompress unit + property tests (core/kv_cache.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import kv_cache as KV
from repro.models.layers import chunked_attention


@settings(max_examples=20, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    hd_blocks=st.integers(1, 3),
    keep=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_roundtrip_error_bounded(s_blocks, hd_blocks, keep, seed):
    """Reconstruction error shrinks as keep grows; keep=8 is quant-only."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, s_blocks * 8, hd_blocks * 8)).astype(np.float32))
    q, s = KV.compress_kv_blocks(x, keep)
    assert q.dtype == jnp.int8
    assert q.shape == (2, s_blocks, hd_blocks, keep, keep)
    back = KV.decompress_kv_blocks(q, s, jnp.float32)
    err = float(jnp.linalg.norm(back - x) / (jnp.linalg.norm(x) + 1e-9))
    if keep == 8:
        assert err < 0.05  # int8 quantization floor
    assert err < 1.05  # never worse than dropping everything (+quant noise)


def test_error_monotone_in_keep():
    rng = np.random.default_rng(0)
    # smooth (1/f-ish) plane: cumulative sum of noise has low-freq energy
    x = jnp.asarray(np.cumsum(rng.standard_normal((1, 32, 64)), axis=1).astype(np.float32))
    errs = []
    for keep in (1, 2, 4, 6, 8):
        q, s = KV.compress_kv_blocks(x, keep)
        back = KV.decompress_kv_blocks(q, s, jnp.float32)
        errs.append(float(jnp.linalg.norm(back - x)))
    assert all(a >= b - 1e-3 for a, b in zip(errs, errs[1:])), errs


def _layer_cache(cfg, b, max_seq, keep, dtype=jnp.float32):
    # f32 tails so oracle comparisons see codec error only (prod uses bf16)
    cache = KV.init_compressed_cache(cfg, b, max_seq, keep=keep, dtype=dtype)
    return {
        "packed_k": cache.packed_k[0], "scale_k": cache.scale_k[0],
        "packed_v": cache.packed_v[0], "scale_v": cache.scale_v[0],
        "tail_k": cache.tail_k[0], "tail_v": cache.tail_v[0],
    }


def test_decode_attention_matches_raw_oracle():
    from repro.configs.base import get_config

    cfg = get_config("yi_6b").reduced()
    b, max_seq, keep = 2, 64, 8
    hd, hkv, h = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_heads
    rng = np.random.default_rng(1)
    ks = jnp.asarray(rng.standard_normal((b, max_seq, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, max_seq, hkv, hd)).astype(np.float32))
    lc = _layer_cache(cfg, b, max_seq, keep)
    for t in range(37):
        lc = KV.update_layer(lc, ks[:, t:t+1], vs[:, t:t+1], jnp.int32(t), keep)
    pos = 36
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    out = KV.attend_compressed(q, lc, jnp.int32(pos), keep, kv_block=16)
    ref = chunked_attention(q, ks[:, :pos+1], vs[:, :pos+1], causal=True, q_offset=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0.02)


def test_tail_only_attention():
    """Positions 0..6: nothing flushed yet — attention over the raw tail."""
    from repro.configs.base import get_config

    cfg = get_config("yi_6b").reduced()
    b, keep = 1, 4
    hd, hkv, h = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_heads
    rng = np.random.default_rng(2)
    ks = jnp.asarray(rng.standard_normal((b, 8, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, 8, hkv, hd)).astype(np.float32))
    lc = _layer_cache(cfg, b, 32, keep)
    for t in range(5):
        lc = KV.update_layer(lc, ks[:, t:t+1], vs[:, t:t+1], jnp.int32(t), keep)
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    out = KV.attend_compressed(q, lc, jnp.int32(4), keep, kv_block=16)
    ref = chunked_attention(q, ks[:, :5], vs[:, :5], causal=True, q_offset=4)
    # tail is raw -> exact (no compression error at all)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_prefill_compress_matches_incremental():
    """Bulk prefill compression == feeding tokens one at a time."""
    from repro.configs.base import get_config

    cfg = get_config("yi_6b").reduced()
    b, s, keep = 2, 24, 6
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    rng = np.random.default_rng(3)
    ks = jnp.asarray(rng.standard_normal((b, s, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, s, hkv, hd)).astype(np.float32))
    bulk = KV.prefill_compress(ks, vs, keep)
    lc = _layer_cache(cfg, b, 32, keep)
    for t in range(s):
        lc = KV.update_layer(lc, ks[:, t:t+1], vs[:, t:t+1], jnp.int32(t), keep)
    nflushed = s // 8
    np.testing.assert_array_equal(
        np.asarray(bulk["packed_k"][:, :nflushed]),
        np.asarray(lc["packed_k"][:, :nflushed]),
    )
    np.testing.assert_allclose(
        np.asarray(bulk["scale_k"][:, :nflushed]),
        np.asarray(lc["scale_k"][:, :nflushed]), rtol=1e-6,
    )


def test_compressed_bytes_accounting():
    from repro.configs.base import get_config

    cfg = get_config("yi_6b").reduced()
    cache = KV.init_compressed_cache(cfg, 2, 64, keep=4)
    per_tok = cache.nbytes_per_token_per_layer()
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    raw = 2 * hkv * hd * 2  # k+v bf16
    assert per_tok < 0.4 * raw  # >2.5x saving at keep=4
