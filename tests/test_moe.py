"""MoE dispatch property tests: token conservation, capacity bounds,
EP-shardability invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_config
from repro.models import layers as L


def _cfg(e=8, k=2, gs=16, dropless=False, cf=2.0):
    import dataclasses
    base = get_config("deepseek_v2_236b").reduced()
    return dataclasses.replace(
        base, n_experts=e, top_k=k, moe_group_size=gs,
        moe_dropless=dropless, moe_capacity_factor=cf,
    )


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([8, 16, 32]),
    e=st.sampled_from([4, 8]),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_output_shape_and_finite(b, s, e, k, seed):
    cfg = _cfg(e=e, k=k, gs=16)
    p = L.moe_init(jax.random.PRNGKey(seed % 1000), cfg, jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    y = L.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_dropless_conserves_every_token():
    """Dropless: every token receives a nonzero expert mixture (with a
    shared expert disabled the routed output must be nonzero for all)."""
    import dataclasses
    cfg = dataclasses.replace(_cfg(dropless=True), n_shared_experts=0)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)).astype(np.float32))
    y = L.moe_ffn(p, x, cfg)
    tok_norm = jnp.linalg.norm(y, axis=-1)
    assert float(jnp.min(tok_norm)) > 0.0


def test_moe_capacity_drops_are_bounded():
    """With tiny capacity, some tokens drop, but the routed output of a
    dropped token is exactly zero (never garbage)."""
    import dataclasses
    cfg = dataclasses.replace(_cfg(cf=0.1), n_shared_experts=0)  # cap floor=8
    p = L.moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)).astype(np.float32))
    y = L.moe_ffn(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_permutation_equivariance_across_rows():
    """Groups are per-batch-row: permuting rows permutes outputs exactly
    (no cross-row interaction through the dispatch)."""
    cfg = _cfg(dropless=True)
    p = L.moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((4, 16, cfg.d_model)).astype(np.float32))
    y = L.moe_ffn(p, x, cfg)
    perm = jnp.asarray([2, 0, 3, 1])
    y_perm = L.moe_ffn(p, x[perm], cfg)
    np.testing.assert_allclose(np.asarray(y[perm]), np.asarray(y_perm),
                               atol=1e-5)


def test_moe_group_size_invariance():
    """Dropless output must not depend on the group partitioning."""
    rng = np.random.default_rng(3)
    outs = []
    for gs in (8, 16, 32):
        cfg = _cfg(gs=gs, dropless=True)
        p = L.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)).astype(np.float32))
        outs.append(np.asarray(L.moe_ffn(p, x, cfg)))
        rng = np.random.default_rng(3)  # same x each round
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(dropless=True)
    p = L.moe_init(jax.random.PRNGKey(4), cfg, jnp.float32)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)).astype(np.float32))
    g = jax.grad(lambda pp: jnp.sum(L.moe_ffn(pp, x, cfg) ** 2))(p)
    for name in ("router", "wg", "wu", "wd"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.linalg.norm(leaf.astype(jnp.float32))) > 0.0, name
