"""Paged compressed KV pool: block-granular allocation, parity and reuse.

The pool is the paper's dynamically-allocated feature-map buffer taken
literally: a shared page pool (one page = one 8-token DCT block group across
all layers) addressed through per-slot block tables, with the serve engine's
host-side free list as the allocator. These tests pin:

  * bitwise greedy parity with the dense pool (uniform + pyramid plans,
    reference backend) while pages are not exhausted,
  * admission blocking on free-page count + freed-page reuse,
  * O(prompt) admission — nothing max_seq-sized in the prefill/splice path,
  * the paged attend primitives (reference gather and fused kernel) against
    the dense layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as KV
from repro.models import api as model_api
from repro.serve import engine as E

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]
PYRAMID = "0-1:keep=8,2-:keep=4"


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i, prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


# ---------------------------------------------------------------------------
# Primitive parity: paged update/attend == dense update/attend
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_update_and_attend_match_dense(lm):
    """Feed the same tokens through a dense layer cache and a paged layer
    cache (host-assigned pages in a scrambled order): flushed blocks land in
    the mapped pages bit-for-bit and attention output is bitwise equal."""
    api, _ = lm
    cfg = api.cfg
    b, max_seq, keep, n_pages = 3, 64, 6, 13
    hd, hkv, h = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_heads
    nh = hd // 8
    rng = np.random.default_rng(1)
    depth = 29
    ks = jnp.asarray(rng.standard_normal((b, depth, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, depth, hkv, hd)).astype(np.float32))

    dense = {
        "packed_k": jnp.zeros((b, max_seq // 8, hkv, nh, keep, keep), jnp.int8),
        "scale_k": jnp.zeros((b, max_seq // 8, hkv, nh), jnp.float32),
        "packed_v": jnp.zeros((b, max_seq // 8, hkv, nh, keep, keep), jnp.int8),
        "scale_v": jnp.zeros((b, max_seq // 8, hkv, nh), jnp.float32),
        "tail_k": jnp.zeros((b, 8, hkv, hd), jnp.float32),
        "tail_v": jnp.zeros((b, 8, hkv, hd), jnp.float32),
    }
    paged = {
        "packed_k": jnp.zeros((n_pages, hkv, nh, keep, keep), jnp.int8),
        "scale_k": jnp.zeros((n_pages, hkv, nh), jnp.float32),
        "packed_v": jnp.zeros((n_pages, hkv, nh, keep, keep), jnp.int8),
        "scale_v": jnp.zeros((n_pages, hkv, nh), jnp.float32),
        "tail_k": jnp.zeros((b, 8, hkv, hd), jnp.float32),
        "tail_v": jnp.zeros((b, 8, hkv, hd), jnp.float32),
    }
    # scrambled host allocation: page for (row, block) in arbitrary order
    perm = rng.permutation(n_pages)
    page_of = {(i, j): int(perm[(i * 4 + j) % n_pages])
               for i in range(b) for j in range(4)}
    table = np.zeros((b, max_seq // 8), np.int32)

    for t in range(depth):
        posv = jnp.full((b,), t, jnp.int32)
        kn, vn = ks[:, t:t + 1], vs[:, t:t + 1]
        dense = KV.update_layer(dense, kn, vn, posv, keep)
        if t % 8 == 7:
            fp = np.array([page_of[(i, t // 8)] for i in range(b)], np.int32)
            for i in range(b):
                table[i, t // 8] = fp[i]
        else:
            fp = np.full((b,), n_pages, np.int32)
        paged = KV.update_layer(paged, kn, vn, posv, keep,
                                flush_page=jnp.asarray(fp))

    # every flushed dense block is bitwise present in its mapped page
    for i in range(b):
        for j in range(depth // 8):
            np.testing.assert_array_equal(
                np.asarray(dense["packed_k"][i, j]),
                np.asarray(paged["packed_k"][table[i, j]]), err_msg=f"{i},{j}")
    np.testing.assert_array_equal(np.asarray(dense["tail_k"]),
                                  np.asarray(paged["tail_k"]))

    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    posq = jnp.full((b,), depth - 1, jnp.int32)
    out_dense = KV.attend_compressed(q, dense, posq, keep, kv_block=16)
    out_paged = KV.attend_compressed(q, paged, posq, keep, kv_block=16,
                                     block_table=jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(out_dense), np.asarray(out_paged))

    # fused kernel path (interpret): block table on the scalar-prefetch side
    from repro.kernels.fused_attend import ops as fa_ops
    out_kern = fa_ops.attend_with_tail(q, paged, posq,
                                       block_table=jnp.asarray(table))
    np.testing.assert_allclose(np.asarray(out_kern), np.asarray(out_dense),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Engine: greedy parity, exhaustion, reuse
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_paged_engine_bitwise_matches_dense(lm, plan):
    """Acceptance criterion: greedy tokens over the paged pool are bitwise
    the dense pool's when pages are not exhausted (uniform + pyramid)."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference")
    dense = E.Engine(api, params, E.ServeConfig(**kw), batch=4)
    base = dense.generate(_requests())
    paged = E.Engine(api, params, E.ServeConfig(**kw, pool_pages=32), batch=4)
    got = paged.generate(_requests())
    assert paged.paged and paged.stats["admit_blocked_on_pages"] == 0
    for a, b in zip(base, got):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    # the whole pool is free again after the workload drains
    assert sorted(paged._free_pages) == list(range(32))


@pytest.mark.slow
def test_pool_exhaustion_blocks_admission_and_reuses_pages(lm):
    """With a pool far smaller than slots x max_seq, admission must block on
    the free-page count (not free slots), resume on retirement with
    RE-ISSUED pages, and still produce the dense engine's tokens."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    base = E.Engine(api, params, E.ServeConfig(**kw), batch=4).generate(_requests())
    eng = E.Engine(api, params, E.ServeConfig(**kw, pool_pages=4), batch=4)

    issued = []
    inner = eng._admit
    def admit_spy(r, cache, slot):
        issued.append(tuple(eng._slot_pages[slot]))
        return inner(r, cache, slot)
    eng._admit = admit_spy

    got = eng.generate(_requests())
    for a, b in zip(base, got):
        assert a.out_tokens == b.out_tokens, (a.uid,)
    assert eng.stats["admit_blocked_on_pages"] > 0   # pages gated admission
    assert eng.stats["peak_pages_in_use"] <= 4
    # pages from retired requests were re-issued to later ones
    flat = [p for pages in issued for p in pages]
    assert len(flat) > len(set(flat)), issued
    assert sorted(eng._free_pages) == list(range(4))  # all returned at drain


def test_request_larger_than_pool_raises(lm):
    api, params = lm
    eng = E.Engine(api, params,
                   E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                                 codec_backend="reference", pool_pages=2),
                   batch=2)
    big = [E.Request(uid=0, prompt=np.zeros(30, np.int32), max_new=30)]
    with pytest.raises(ValueError, match="pages"):
        eng.generate(big)


def test_failed_admission_releases_reserved_pages(lm):
    """A prompt whose bucket overruns max_seq raises AFTER pages were
    reserved (the page gate clamps to max_seq, the bucket check doesn't):
    the reservation must roll back so the pool can't leak and later
    generate() calls still have the full pool."""
    api, params = lm
    eng = E.Engine(api, params,
                   E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                                 codec_backend="reference", pool_pages=16),
                   batch=2)
    too_long = [E.Request(uid=0, prompt=np.zeros(70, np.int32), max_new=2)]
    with pytest.raises(ValueError, match="bucket"):
        eng.generate(too_long)
    assert sorted(eng._free_pages) == list(range(16))  # nothing leaked
    ok = eng.generate(_requests(n=3))
    assert all(r.done for r in ok)


def test_paged_requires_compressed_continuous(lm):
    api, params = lm
    with pytest.raises(ValueError, match="paged"):
        E.Engine(api, params, E.ServeConfig(max_seq=64, pool_pages=8), batch=2)
    with pytest.raises(ValueError, match="continuous"):
        E.Engine(api, params,
                 E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                               pool_pages=8),
                 batch=2, scheduler="static")


def test_page_budget_solves_page_count(lm):
    """page_budget_mb -> pages via the plan's per-layer page accounting."""
    api, params = lm
    plan = E.ServeConfig(kv_compress=True, kv_keep=8).resolved_plan()
    page_b = plan.page_bytes(api.cfg)
    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference",
                       page_budget_mb=10 * page_b / 1e6)
    assert sc.resolved_pool_pages(api.cfg) == 10
    eng = E.Engine(api, params, sc, batch=2)
    assert eng._n_pages == 10
    with pytest.raises(ValueError, match="no page"):
        E.ServeConfig(kv_compress=True, kv_keep=8,
                      page_budget_mb=page_b / 1e6 / 2).resolved_pool_pages(api.cfg)


# ---------------------------------------------------------------------------
# Admission cost: nothing max_seq-sized in the paged prefill/splice path
# ---------------------------------------------------------------------------

def test_paged_admission_never_materializes_max_seq(lm):
    """The dense path zero-fills a max_seq-deep store per admission; the
    paged path must scale with the prompt bucket only.  Checked on compiled
    shapes: every output of the paged prefill (and every operand of its
    HLO) is bucket-sized, while the dense prefill's store is max_seq-sized."""
    api, params = lm
    # large pool depth, chosen so max_seq/8 = 344 collides with no model dim
    max_seq = 2752
    bucket, plen = 16, 12
    tokens = jnp.zeros((1, bucket), jnp.int32)
    lengths = jnp.asarray([plen], jnp.int32)

    sc_dense = E.ServeConfig(max_seq=max_seq, kv_compress=True, kv_keep=8,
                             codec_backend="reference")
    pre_d, _, _, _ = E.make_steps(api, sc_dense)
    _, dense_cache = jax.eval_shape(pre_d, params, tokens, lengths)
    assert dense_cache.segments[0].packed_k.shape[2] == max_seq // 8

    sc_paged = E.ServeConfig(max_seq=max_seq, kv_compress=True, kv_keep=8,
                             codec_backend="reference", pool_pages=8)
    pre_p, _, _, _ = E.make_steps(api, sc_paged)
    _, upd = jax.eval_shape(pre_p, params, tokens, lengths)
    for seg in upd:
        for name, leaf in seg.items():
            assert max_seq // 8 not in leaf.shape, (name, leaf.shape)
            assert leaf.shape[2] in (bucket // 8, 8), (name, leaf.shape)

    # compiled-HLO check: no operand anywhere in the paged prefill carries
    # the max_seq block depth (StableHLO renders shapes 'tensor<1x344x...>',
    # so match the x-delimited dim). Positive control first: the DENSE
    # prefill's lowering must contain it — else the pattern is vacuous.
    dim = f"x{max_seq // 8}x"
    txt_dense = jax.jit(pre_d).lower(params, tokens, lengths).as_text()
    assert dim in txt_dense, "positive control failed: pattern never matches"
    txt = jax.jit(pre_p).lower(params, tokens, lengths).as_text()
    assert dim not in txt


# ---------------------------------------------------------------------------
# Pool container + accounting
# ---------------------------------------------------------------------------

def test_paged_cache_geometry_and_page_bytes(lm):
    api, _ = lm
    cfg = api.cfg
    cache = KV.init_paged_cache(cfg, batch=3, max_seq=64, n_pages=11,
                                plan=PYRAMID)
    assert cache.n_pages == 11
    assert cache.max_seq == 64
    assert cache.block_table.shape == (3, 8)
    assert [s.keep for s in cache.segments] == [8, 4]
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    want = sum((s.stop - s.start) * KV.block_group_bytes(s.keep, hkv, hd)
               for s in cache.segments)
    assert cache.page_bytes() == want
    # the plan-level accounting (ServeConfig.page_budget_mb's solver) agrees
    from repro.codec import plan as plan_lib
    assert plan_lib.as_plan(PYRAMID).page_bytes(cfg) == want
