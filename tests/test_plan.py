"""CompressionPlan API tests: spec roundtrip, legacy-scalar parity, the
budget solver, and a non-uniform plan working end-to-end through the
continuous-batching serve engine (acceptance criteria of the per-layer
policy redesign)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codec.plan import BLOCK, CompressionPlan, LayerPolicy, as_plan
from repro.core import kv_cache as KV
from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.serve import engine as E


# ---------------------------------------------------------------------------
# Spec strings
# ---------------------------------------------------------------------------

def test_spec_parse_examples():
    p = CompressionPlan.from_spec("0-3:keep=6,4-:keep=3")
    assert p.keeps(6) == (6, 6, 6, 6, 3, 3)
    assert p.segments(6) == (
        (0, 4, LayerPolicy(keep=6)), (4, 6, LayerPolicy(keep=3)))
    # single-layer entry, flags, backend, first-match override
    q = CompressionPlan.from_spec("2:keep=8+backend=reference,0-:keep=4+bits=6")
    assert q.policy(2) == LayerPolicy(keep=8, backend="reference")
    assert q.policy(0) == LayerPolicy(keep=4, bits=6)
    off = CompressionPlan.from_spec("3-:off,0-:keep=5")
    assert off.policy(1).enabled and not off.policy(3).enabled
    with pytest.raises(ValueError):
        CompressionPlan.from_spec("nope")
    with pytest.raises(ValueError):
        CompressionPlan.from_spec("0-3:keep=99")


def test_spec_roundtrip_hypothesis():
    pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
    from hypothesis import given, settings
    from hypothesis import strategies as st

    policies = st.builds(
        LayerPolicy,
        keep=st.integers(1, 8),
        bits=st.sampled_from([4, 6, 8]),
        enabled=st.booleans(),
        backend=st.sampled_from([None, "reference", "pallas"]),
    )

    @st.composite
    def rules(draw):
        start = draw(st.integers(0, 30))
        stop = draw(st.one_of(st.none(), st.integers(start + 1, 40)))
        return (start, stop, draw(policies))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(rules(), min_size=1, max_size=5))
    def roundtrip(rule_list):
        plan = CompressionPlan(rules=tuple(rule_list))
        back = CompressionPlan.from_spec(plan.to_spec())
        assert back.rules == plan.rules
        assert back.policies(16) == plan.policies(16)

    roundtrip()


def test_as_plan_spellings():
    assert as_plan(None, keep=6) == CompressionPlan.uniform(6)
    assert as_plan(5) == CompressionPlan.uniform(5)
    assert as_plan("0-:keep=3") == CompressionPlan.from_spec("0-:keep=3")
    p = as_plan("0-:keep=3", backend="reference")
    assert p.policy(0).backend == "reference"
    with pytest.raises(TypeError):
        as_plan(3.5)


def test_pyramid_is_gentle_early_aggressive_late():
    keeps = CompressionPlan.pyramid(8, keep_first=8, keep_last=3).keeps(8)
    assert keeps[0] == 8 and keeps[-1] == 3
    assert all(a >= b for a, b in zip(keeps, keeps[1:]))


# ---------------------------------------------------------------------------
# Budget solver
# ---------------------------------------------------------------------------

def test_from_budget_fits_and_is_monotone():
    cfg = model_api.get_config("yi_6b").reduced()
    max_seq, batch = 64, 2
    full = CompressionPlan.uniform(8).kv_cache_bytes(cfg, max_seq, batch=batch)
    prev_keeps = None
    for frac in (1.0, 0.8, 0.6, 0.45):
        budget = full * frac
        plan = CompressionPlan.from_budget(cfg, max_seq, budget, batch=batch)
        got = plan.kv_cache_bytes(cfg, max_seq, batch=batch)
        assert got <= budget, (frac, got, budget)
        keeps = plan.keeps(cfg.n_layers)
        if prev_keeps is not None:  # smaller budget => pointwise <= keeps
            assert all(a <= b for a, b in zip(keeps, prev_keeps)), (keeps, prev_keeps)
        prev_keeps = keeps
    # the solved plan's analytic bytes match the allocated pool exactly
    cache = KV.init_compressed_cache(cfg, batch, max_seq, plan=plan,
                                     dtype=jnp.bfloat16)
    assert cache.storage_stats()["kv_bytes"] == plan.kv_cache_bytes(
        cfg, max_seq, batch=batch)


def test_from_budget_infeasible_raises():
    cfg = model_api.get_config("yi_6b").reduced()
    with pytest.raises(ValueError):
        CompressionPlan.from_budget(cfg, 64, 1.0)


# ---------------------------------------------------------------------------
# Uniform-plan vs legacy-scalar bitwise parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def test_uniform_plan_matches_legacy_scalar_kv(lm):
    """plan=uniform(k) and kv_keep=k produce bitwise-identical prefill and
    compressed-cache decode logits."""
    api, params = lm
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, api.cfg.vocab_size, (2, 24)).astype(np.int32))
    legacy = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=6,
                           codec_backend="reference")
    planned = E.ServeConfig(max_seq=64, kv_compress=True,
                            plan=CompressionPlan.uniform(6),
                            codec_backend="reference")
    pf_a, dec_a, _, _ = E.make_steps(api, legacy)
    pf_b, dec_b, _, _ = E.make_steps(api, planned)
    la, ca = pf_a(params, toks)
    lb, cb = pf_b(params, toks)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    t = jnp.argmax(la[:, -1], -1).astype(jnp.int32)
    for s in range(5):
        la, ca = dec_a(params, t, ca, jnp.int32(24 + s))
        lb, cb = dec_b(params, t, cb, jnp.int32(24 + s))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        t = jnp.argmax(la, -1).astype(jnp.int32)


def test_uniform_plan_matches_legacy_scalar_actcompress(lm):
    """ActCompress grads are bitwise-identical between compress_keep=k and
    plan=uniform(k) (the shim is a pure respelling)."""
    api, params = lm
    ts = TokenStream(vocab_size=api.cfg.vocab_size, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in ts.batch(0).items()}

    def grads(**kw):
        return jax.grad(
            lambda p: api.loss(p, batch, remat="compressed", **kw)[0])(params)

    g_legacy = grads(compress_keep=6, codec_backend="reference")
    g_plan = grads(plan=CompressionPlan.uniform(6, backend="reference"))
    g_spec = grads(plan="0-:keep=6+backend=reference")
    for a, b, c in zip(jax.tree.leaves(g_legacy), jax.tree.leaves(g_plan),
                       jax.tree.leaves(g_spec)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_segmented_actcompress_runs_and_descends(lm):
    """A non-uniform ActCompress plan (scan split per segment) still yields
    finite, descent-aligned gradients."""
    api, params = lm
    ts = TokenStream(vocab_size=api.cfg.vocab_size, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in ts.batch(0).items()}
    g_none = jax.grad(lambda p: api.loss(p, batch, remat="none")[0])(params)
    g_seg = jax.grad(lambda p: api.loss(
        p, batch, remat="compressed", plan="0-1:keep=8,2-:keep=6")[0])(params)
    num = sum(float(jnp.sum(a * b)) for a, b in
              zip(jax.tree.leaves(g_none), jax.tree.leaves(g_seg)))
    na = np.sqrt(sum(float(jnp.sum(a * a)) for a in jax.tree.leaves(g_none)))
    nb = np.sqrt(sum(float(jnp.sum(b * b)) for b in jax.tree.leaves(g_seg)))
    assert np.isfinite(num) and num / (na * nb) > 0.5


# ---------------------------------------------------------------------------
# Per-layer geometry in the KV cache
# ---------------------------------------------------------------------------

def test_cache_segments_have_per_layer_geometry():
    cfg = model_api.get_config("yi_6b").reduced()
    plan = CompressionPlan.from_spec("0-1:keep=6,2-:keep=3")
    cache = KV.init_compressed_cache(cfg, 2, 32, plan=plan)
    assert [(s.start, s.stop, s.keep) for s in cache.segments] == \
        [(0, 2, 6), (2, 4, 3)]
    assert cache.segments[0].packed_k.shape[-2:] == (6, 6)
    assert cache.segments[1].packed_k.shape[-2:] == (3, 3)
    assert cache.keeps == (6, 6, 3, 3)
    with pytest.raises(ValueError):
        cache.packed_k  # single-store view is only for uniform plans
    # uniform plans keep the legacy single-store view
    uni = KV.init_compressed_cache(cfg, 2, 32, keep=4)
    assert uni.keep == 4 and uni.packed_k.shape[0] == cfg.n_layers
    # slot reset reaches every segment
    dirty = jax.tree.map(lambda a: a + jnp.ones_like(a), cache)
    wiped = KV.cache_reset_slot(dirty, 1)
    for seg in wiped.segments:
        for leaf in jax.tree.leaves(seg):
            arr = np.asarray(leaf)
            assert (arr[:, 1] == 0).all() and (arr[:, 0] != 0).any()


# ---------------------------------------------------------------------------
# Acceptance: non-uniform plan end-to-end through the serve engine
# ---------------------------------------------------------------------------

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]


def _requests(seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i, prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(8)]


@pytest.fixture(scope="module")
def trained_lm():
    """Briefly trained reduced model: peaked logits make greedy argmax
    robust to the (small) keep=6-level reconstruction error, as in real
    serving — random-init logits are argmax-flipping white noise."""
    from repro.optim.adamw import AdamWConfig
    from repro.train import step as train_step

    api = model_api.build_reduced("yi_6b")
    ts = TokenStream(vocab_size=api.cfg.vocab_size, seq_len=64, global_batch=8)
    tc = train_step.TrainConfig(
        microbatches=1, remat="full", param_dtype=jnp.float32,
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=60))
    state = train_step.init_train_state(api, tc)
    step = jax.jit(train_step.make_train_step(
        api, jax.make_mesh((1,), ("data",)), tc), donate_argnums=(0,))
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
        state, _ = step(state, b)
    return api, state["params"]


@pytest.mark.slow
def test_pyramid_plan_serves_like_uniform_with_smaller_footprint(trained_lm):
    """Acceptance: a pyramid plan through the continuous-batching engine
    reproduces the uniform-plan greedy outputs on the tested prompts while
    storage_stats reports a strictly smaller compressed KV footprint."""
    api, params = trained_lm
    cfg = api.cfg
    pyr_plan = CompressionPlan.pyramid(cfg.n_layers, keep_first=8, keep_last=6)
    assert len(set(pyr_plan.keeps(cfg.n_layers))) > 1  # genuinely non-uniform
    uni = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                        codec_backend="reference")
    pyr = E.ServeConfig(max_seq=64, kv_compress=True, plan=pyr_plan,
                        codec_backend="reference")
    out_u = E.Engine(api, params, uni, batch=4).generate(_requests())
    out_p = E.Engine(api, params, pyr, batch=4).generate(_requests())
    for u, p in zip(out_u, out_p):
        assert p.done and p.out_tokens == u.out_tokens, (p.uid,)
    su = KV.init_compressed_cache(cfg, 4, 64, keep=8).storage_stats()
    sp = KV.init_compressed_cache(cfg, 4, 64, plan=pyr_plan).storage_stats()
    assert sp["kv_bytes"] < su["kv_bytes"]
    assert sp["keeps"] == pyr_plan.keeps(cfg.n_layers)


def test_moe_segments_cross_stack_boundary():
    """A plan segment straddling the dense/moe param-stack boundary decodes
    through the compressed pool (segment x stack intersection scans)."""
    api = model_api.build_reduced("moonshot_v1_16b_a3b")
    cfg = api.cfg
    assert cfg.family == "moe" and cfg.first_k_dense == 1
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32))
    sc = E.ServeConfig(max_seq=32, kv_compress=True,
                       plan="0-1:keep=8,2-:keep=5", codec_backend="reference")
    pf, dec, _, _ = E.make_steps(api, sc)
    logits, cache = pf(params, toks)
    assert [(s.start, s.stop, s.keep) for s in cache.segments] == \
        [(0, 2, 8), (2, 4, 5)]
    t = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    for s in range(3):
        logits, cache = dec(params, t, cache, jnp.int32(16 + s))
        t = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_storage_accounting_single_definition():
    """Pool reports, codec stats and the analytic plan accounting all derive
    from `codec.api.tile_bytes` — pin them against each other AND against
    the literal array buffers so the definitions can't drift again."""
    from repro import codec
    from repro.codec.api import tile_bytes

    cfg = model_api.get_config("yi_6b").reduced()
    batch, max_seq = 3, 64
    plan = as_plan("0-1:keep=8,2-:keep=4")
    cache = KV.init_compressed_cache(cfg, batch, max_seq, plan=plan,
                                     dtype=jnp.bfloat16)

    for seg in cache.segments:
        literal = (seg.packed_k.size + seg.packed_v.size            # int8
                   + 4 * (seg.scale_k.size + seg.scale_v.size)      # f32
                   + 2 * (seg.tail_k.size + seg.tail_v.size))       # bf16
        assert seg.nbytes() == literal

    # cache report == plan analytic == engine pool report (eval_shape bytes)
    assert cache.storage_stats()["kv_bytes"] == \
        plan.kv_cache_bytes(cfg, max_seq, batch=batch)
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = E.Engine(api, params,
                   E.ServeConfig(max_seq=max_seq, kv_compress=True, plan=plan,
                                 codec_backend="reference"), batch=batch)
    assert eng.kv_pool_stats()["kv_pool_bytes"] == \
        plan.kv_cache_bytes(cfg, max_seq, batch=batch)

    # per-token view matches the codec container's bytes-per-element
    for keep in (3, 4, 6, 8):
        c = codec.compress(jnp.ones((16, 16), jnp.float32), keep=keep)
        assert c.nbytes_per_element() == tile_bytes(keep) / 64
        stats = codec.storage_stats(c)
        assert stats["compressed_bits"] == \
            (16 // 8) * (16 // 8) * tile_bytes(keep) * 8
        uni = KV.init_compressed_cache(cfg, 1, 64, keep=keep)
        hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
        assert uni.nbytes_per_token_per_layer() == \
            KV.block_group_bytes(keep, hkv, hd) / 8 == \
            2 * hkv * hd * c.nbytes_per_element()

    # the paged pool charges the same per-block definition
    paged = KV.init_paged_cache(cfg, batch, max_seq, n_pages=10, plan=plan)
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    assert paged.page_bytes() == plan.page_bytes(cfg) == sum(
        KV.block_group_bytes(k, hkv, hd) for k in plan.keeps(cfg.n_layers))
