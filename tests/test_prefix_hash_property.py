"""Property tests for the prefix-sharing content hash (hypothesis).

The sharing contract rests on `tiering.prefix_block_keys` being a pure
chained function of the prompt TOKENS: key j commits to tokens[0:8(j+1)]
and to nothing else — not the admission bucket the prompt is padded to,
not the batch row it lands in, not trailing partial-block tokens. These
properties are what make "same key => same K/V" sound (up to hash
collision, which the engine closes by verifying candidate pages bitwise
on device — pinned in test_tiered_pool.py's collision test).

A hypothesis-free mirror of the core properties runs unconditionally in
test_tiered_pool.py, so CI without hypothesis still covers them.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import tiering

tokens = st.lists(st.integers(0, 2**31 - 1), min_size=0, max_size=64)


@settings(max_examples=200, deadline=None)
@given(tokens)
def test_key_count_and_determinism(toks):
    arr = np.asarray(toks, np.int32)
    keys = tiering.prefix_block_keys(arr)
    assert len(keys) == len(arr) // tiering.BLOCK  # full blocks only
    assert keys == tiering.prefix_block_keys(arr)  # pure function


@settings(max_examples=200, deadline=None)
@given(tokens, tokens)
def test_padding_and_extension_invariance(toks, pad):
    """Appending ANYTHING (bucket padding, a batch row's tail, more prompt)
    never changes the keys of the already-complete blocks."""
    arr = np.asarray(toks, np.int32)
    padded = np.concatenate([arr, np.asarray(pad, np.int32)])
    base = tiering.prefix_block_keys(arr)
    ext = tiering.prefix_block_keys(padded)
    assert ext[:len(base)] == base


@settings(max_examples=200, deadline=None)
@given(tokens.filter(lambda t: len(t) >= tiering.BLOCK),
       st.data())
def test_chained_keys_diverge_at_first_differing_block(toks, data):
    """Flip one token: every key from that block ON differs (the chain
    commits each key to the whole prefix), keys before it are untouched."""
    arr = np.asarray(toks, np.int32)
    nb = len(arr) // tiering.BLOCK
    i = data.draw(st.integers(0, nb * tiering.BLOCK - 1))
    mut = arr.copy()
    mut[i] = mut[i] ^ 1
    a, b = tiering.prefix_block_keys(arr), tiering.prefix_block_keys(mut)
    blk = i // tiering.BLOCK
    assert a[:blk] == b[:blk]
    assert all(x != y for x, y in zip(a[blk:], b[blk:]))


@settings(max_examples=100, deadline=None)
@given(tokens, tokens)
def test_prefix_agreement_iff_leading_keys_agree(ta, tb):
    """keys_a[j] == keys_b[j] exactly when the two prompts agree on the
    whole prefix through block j (no collisions at 128-bit blake2b within
    hypothesis's reach — and the engine never trusts this without a
    device-side bitwise check anyway)."""
    a = np.asarray(ta, np.int32)
    b = np.asarray(tb, np.int32)
    ka, kb = tiering.prefix_block_keys(a), tiering.prefix_block_keys(b)
    for j in range(min(len(ka), len(kb))):
        end = (j + 1) * tiering.BLOCK
        same_prefix = bool(np.array_equal(a[:end], b[:end]))
        assert (ka[j] == kb[j]) == same_prefix
