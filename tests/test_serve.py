"""Serving-stack tests: prefill==forward, compressed-vs-raw decode drift,
engine batching semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api as model_api
from repro.models import transformer as T
from repro.serve import engine as E


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def test_prefill_logits_match_forward(lm):
    api, params = lm
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, api.cfg.vocab_size, (2, 16)).astype(np.int32))
    logits_fwd = api.forward(params, {"tokens": toks}, remat="none")
    logits_pf, cache = T.prefill(params, toks, api.cfg, 32, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_fwd),
                               atol=1e-4)


def test_prefill_cache_continues_decode(lm):
    """prefill cache + decode_step == teacher-forced forward at the next pos."""
    api, params = lm
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, api.cfg.vocab_size, (2, 17)).astype(np.int32))
    logits_pf, cache = T.prefill(params, toks[:, :16], api.cfg, 32,
                                 cache_dtype=jnp.float32)
    logits_dec, _ = api.decode_step(params, toks[:, 16], cache, jnp.int32(16))
    full = api.forward(params, {"tokens": toks}, remat="none")
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(full[:, -1]),
                               atol=1e-3)


def test_compressed_decode_tracks_raw(lm):
    api, params = lm
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, api.cfg.vocab_size, (2, 24)).astype(np.int32))
    pf_r, dec_r, _, vec_r = E.make_steps(api, E.ServeConfig(max_seq=64))
    pf_c, dec_c, _, vec_c = E.make_steps(api, E.ServeConfig(max_seq=64, kv_compress=True,
                                                            kv_keep=8))
    assert vec_r and vec_c  # transformer families support per-slot positions
    lr, cr = pf_r(params, toks)
    lc, cc = pf_c(params, toks)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lc), atol=1e-4)
    t = jnp.argmax(lr[:, -1], -1).astype(jnp.int32)
    drift = 0.0
    for s in range(8):
        lr2, cr = dec_r(params, t, cr, jnp.int32(24 + s))
        lc2, cc = dec_c(params, t, cc, jnp.int32(24 + s))
        drift = max(drift, float(jnp.max(jnp.abs(lr2 - lc2))))
        t = jnp.argmax(lr2, -1).astype(jnp.int32)
    assert drift < 0.1, drift


def test_recurrent_prefill_rwkv():
    api = model_api.build_reduced("rwkv6_1_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, api.cfg.vocab_size, (2, 12)).astype(np.int32))
    pf, dec, _, vec = E.make_steps(api, E.ServeConfig(max_seq=32))
    assert not vec  # recurrent families keep the scalar step index
    logits_seq, cache = pf(params, toks)
    full = api.forward(params, {"tokens": toks}, remat="none")
    np.testing.assert_allclose(np.asarray(logits_seq[:, -1]),
                               np.asarray(full[:, -1]), atol=1e-3)


def test_engine_batching_and_eos(lm):
    api, params = lm
    sc = E.ServeConfig(max_seq=64, temperature=0.0)
    eng = E.Engine(api, params, sc, batch=4)
    rng = np.random.default_rng(4)
    reqs = [E.Request(uid=i, prompt=rng.integers(0, 200, 6 + i).astype(np.int32),
                      max_new=4 + i) for i in range(3)]
    done = eng.generate(reqs)
    assert [r.uid for r in done] == [0, 1, 2]
    for i, r in enumerate(done):
        assert len(r.out_tokens) == 4 + i
        assert r.done
    assert eng.stats["requests"] == 3


def test_engine_determinism(lm):
    api, params = lm
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, 200, 8).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = E.Engine(api, params, E.ServeConfig(max_seq=64), batch=2)
        r = eng.generate([E.Request(uid=0, prompt=prompt.copy(), max_new=6)])[0]
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]


def test_whisper_encdec_generate():
    """Whisper has no incremental decode (448-token cap); serving is
    re-forward greedy decoding over the growing prefix. Deterministic,
    finite, and consistent with teacher forcing."""
    api = model_api.build_reduced("whisper_base")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    cfg = api.cfg
    rng = np.random.default_rng(7)
    frames = jnp.asarray(rng.standard_normal((2, cfg.encoder_seq_len or 16, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 4)).astype(np.int32))

    def greedy(n):
        cur = toks
        for _ in range(n):
            logits = api.forward(params, {"frames": frames, "tokens": cur},
                                 remat="none")
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
        return cur

    out1, out2 = greedy(5), greedy(5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 9)
    # teacher-forced consistency: feeding the generated prefix reproduces
    # the same next-token argmax at every position
    logits = api.forward(params, {"frames": frames, "tokens": out1[:, :-1]},
                         remat="none")
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits[:, 3:-1], -1)), np.asarray(out1[:, 4:-1]))
