"""Continuous-batching serve tests: per-slot positions through the
compressed KV store, slot retirement/re-admission, and parity of batched
slots against single-request runs (reference backend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as KV
from repro.models import api as model_api
from repro.serve import engine as E

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i, prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


# ---------------------------------------------------------------------------
# Per-slot position vectors in the cache primitives
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_update_and_attend_vector_pos_match_per_row_scalar(lm):
    """One batched run with per-slot positions == each row's scalar run."""
    api, _ = lm
    cfg = api.cfg
    b, max_seq, keep = 3, 64, 6
    hd, hkv, h = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_heads
    rng = np.random.default_rng(1)
    depths = [12, 23, 37]
    ks = jnp.asarray(rng.standard_normal((b, max(depths), hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, max(depths), hkv, hd)).astype(np.float32))
    cache = KV.init_compressed_cache(cfg, b, max_seq, keep=keep, dtype=jnp.float32)
    lc0 = {"packed_k": cache.packed_k[0], "scale_k": cache.scale_k[0],
           "packed_v": cache.packed_v[0], "scale_v": cache.scale_v[0],
           "tail_k": cache.tail_k[0], "tail_v": cache.tail_v[0]}

    lc_vec = dict(lc0)
    for t in range(max(depths)):
        posv = jnp.asarray([min(t, d - 1) for d in depths], jnp.int32)
        kn = jnp.stack([ks[i, min(t, depths[i] - 1)] for i in range(b)])[:, None]
        vn = jnp.stack([vs[i, min(t, depths[i] - 1)] for i in range(b)])[:, None]
        lc_vec = KV.update_layer(lc_vec, kn, vn, posv, keep)

    for i, d in enumerate(depths):
        lci = {k: v[i:i + 1] for k, v in lc0.items()}
        for t in range(d):
            lci = KV.update_layer(lci, ks[i:i + 1, t:t + 1], vs[i:i + 1, t:t + 1],
                                  jnp.int32(t), keep)
        for key in lci:
            np.testing.assert_array_equal(
                np.asarray(lc_vec[key][i:i + 1]), np.asarray(lci[key]),
                err_msg=f"row {i} key {key}")

    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)).astype(np.float32))
    posq = jnp.asarray([d - 1 for d in depths], jnp.int32)
    out_vec = KV.attend_compressed(q, lc_vec, posq, keep, kv_block=16)
    for i, d in enumerate(depths):
        lci = {k: v[i:i + 1] for k, v in lc_vec.items()}
        oi = KV.attend_compressed(q[i:i + 1], lci, jnp.int32(d - 1), keep, kv_block=16)
        np.testing.assert_allclose(np.asarray(out_vec[i:i + 1]), np.asarray(oi),
                                   atol=1e-6)
    # fused kernel wrapper takes the same vector
    from repro.kernels.fused_attend import ops as fa_ops
    o_kern = fa_ops.attend_with_tail(q, lc_vec, posq, tile_s=16)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(out_vec), atol=1e-4)


@pytest.mark.slow
def test_prefill_compress_per_row_lengths(lm):
    """Bulk prefill with per-row lengths == per-row incremental feeds."""
    api, _ = lm
    cfg = api.cfg
    b, s, keep = 3, 40, 6
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    rng = np.random.default_rng(3)
    ks = jnp.asarray(rng.standard_normal((b, s, hkv, hd)).astype(np.float32))
    vs = jnp.asarray(rng.standard_normal((b, s, hkv, hd)).astype(np.float32))
    lens = [12, 23, 37]
    bulk = KV.prefill_compress(ks, vs, keep, pos=jnp.asarray(lens, jnp.int32))
    cache = KV.init_compressed_cache(cfg, b, 64, keep=keep, dtype=jnp.float32)
    lc = {"packed_k": cache.packed_k[0], "scale_k": cache.scale_k[0],
          "packed_v": cache.packed_v[0], "scale_v": cache.scale_v[0],
          "tail_k": cache.tail_k[0], "tail_v": cache.tail_v[0]}
    for t in range(max(lens)):
        posv = jnp.asarray([min(t, d - 1) for d in lens], jnp.int32)
        kn = jnp.stack([ks[i, min(t, lens[i] - 1)] for i in range(b)])[:, None]
        vn = jnp.stack([vs[i, min(t, lens[i] - 1)] for i in range(b)])[:, None]
        lc = KV.update_layer(lc, kn, vn, posv, keep)
    for i, d in enumerate(lens):
        nfl = d // 8
        np.testing.assert_array_equal(np.asarray(bulk["packed_k"][i, :nfl]),
                                      np.asarray(lc["packed_k"][i, :nfl]))
        fl = nfl * 8
        np.testing.assert_allclose(np.asarray(bulk["tail_k"][i, :d - fl]),
                                   np.asarray(ks[i, fl:d]), atol=0)


def test_cache_reset_slot(lm):
    api, _ = lm
    cfg = api.cfg
    cache = KV.init_compressed_cache(cfg, 3, 32, keep=4, dtype=jnp.float32)
    dirty = jax.tree.map(lambda a: a + jnp.ones_like(a), cache)
    wiped = KV.cache_reset_slot(dirty, 1)
    for name in ("packed_k", "scale_k", "packed_v", "scale_v", "tail_k", "tail_v"):
        arr = np.asarray(getattr(wiped, name))
        assert (arr[:, 1] == 0).all(), name
        assert (arr[:, 0] != 0).any() and (arr[:, 2] != 0).any(), name


# ---------------------------------------------------------------------------
# Engine: continuous scheduling semantics
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_continuous_matches_single_request_runs_compressed(lm):
    """8 requests, distinct prompt lengths/budgets, 4 slots, compressed KV:
    greedy per-request outputs == running each request alone (acceptance
    criterion), with every request prefilled exactly once."""
    api, params = lm
    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference")
    eng = E.Engine(api, params, sc, batch=4)
    admissions = []
    inner_admit = eng._admit
    eng._admit = lambda r, c, i: admissions.append(r.uid) or inner_admit(r, c, i)
    reqs = _requests()
    done = eng.generate(reqs)
    assert [r.uid for r in done] == list(range(8))
    assert sorted(admissions) == list(range(8))  # one prefill per request
    assert eng.stats["requests"] == 8
    assert eng.stats["tokens_out"] == sum(MAX_NEWS)

    solo = E.Engine(api, params, sc, batch=1)
    for r, want in zip(_requests(), done):
        solo.generate([r])
        assert r.out_tokens == want.out_tokens, (r.uid, r.out_tokens, want.out_tokens)


@pytest.mark.slow
def test_continuous_matches_single_request_runs_mla():
    """MLA (latent cache) continuous batching == solo runs: pins the per-row
    scatter on c_kv/k_rope and the per-row horizon in mla_decode_attention."""
    api = model_api.build_reduced("deepseek_v2_236b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    sc = E.ServeConfig(max_seq=64, kv_compress=True)  # MLA falls back to raw latent
    shapes = [(5, 4), (11, 6), (7, 3)]

    def reqs():
        rng = np.random.default_rng(0)
        return [E.Request(uid=i, prompt=rng.integers(0, 200, n).astype(np.int32),
                          max_new=m) for i, (n, m) in enumerate(shapes)]

    eng = E.Engine(api, params, sc, batch=2)
    assert eng.scheduler == "continuous"
    done = eng.generate(reqs())
    solo = E.Engine(api, params, sc, batch=1)
    for r, want in zip(reqs(), done):
        solo.generate([r])
        assert r.out_tokens == want.out_tokens, (r.uid, r.out_tokens, want.out_tokens)


@pytest.mark.slow
def test_continuous_matches_single_request_runs_raw(lm):
    api, params = lm
    sc = E.ServeConfig(max_seq=64)
    eng = E.Engine(api, params, sc, batch=3)
    done = eng.generate(_requests(n=5))
    solo = E.Engine(api, params, sc, batch=1)
    for r, want in zip(_requests(n=5), done):
        solo.generate([r])
        assert r.out_tokens == want.out_tokens, (r.uid,)


@pytest.mark.slow
def test_midstream_eos_retires_and_reuses_slot(lm):
    """EOS mid-stream retires the slot; the freed slot serves queued work."""
    api, params = lm
    base = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                         codec_backend="reference")
    probe = E.Engine(api, params, base, batch=2).generate(_requests())
    # pick a token that appears mid-stream (not first) in some output
    eos = next(t for r in probe for t in r.out_tokens[1:-1])
    truncated = [r.out_tokens.index(eos) + 1 if eos in r.out_tokens
                 else len(r.out_tokens) for r in probe]

    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference", eos_id=eos)
    eng = E.Engine(api, params, sc, batch=2)
    done = eng.generate(_requests())
    assert eng.stats["requests"] == 8  # 8 requests over 2 slots => reuse
    for r, want_len, ref in zip(done, truncated, probe):
        assert r.done
        assert len(r.out_tokens) == want_len
        assert r.out_tokens == ref.out_tokens[:want_len], r.uid
        if eos in r.out_tokens:
            assert r.out_tokens[-1] == eos and eos not in r.out_tokens[:-1]


def test_finish_at_admission_runs_no_decode_step(lm):
    """max_new=1: the only token comes from prefill logits; the engine must
    not run (or sample from) a decode step."""
    api, params = lm
    for scheduler in ("continuous", "static"):
        eng = E.Engine(api, params, E.ServeConfig(max_seq=64), batch=4,
                       scheduler=scheduler)
        rng = np.random.default_rng(7)
        reqs = [E.Request(uid=i, prompt=rng.integers(0, 200, 6 + i).astype(np.int32),
                          max_new=1) for i in range(3)]
        done = eng.generate(reqs)
        assert eng.stats["steps"] == 0, scheduler
        assert all(len(r.out_tokens) == 1 and r.done for r in done)


def test_generate_does_not_mutate_caller_list(lm):
    api, params = lm
    for scheduler in ("continuous", "static"):
        eng = E.Engine(api, params, E.ServeConfig(max_seq=64), batch=4,
                       scheduler=scheduler)
        reqs = _requests(n=2)
        out = eng.generate(reqs)
        assert len(reqs) == 2, scheduler  # no dummy-slot padding appended
        assert out is not reqs
        assert [r.uid for r in out] == [0, 1]


def test_context_exhaustion_truncates_both_schedulers(lm):
    """A request whose budget would overrun max_seq retires truncated (the
    cache cannot hold another token) instead of silently dropping K/V
    writes and generating from a stale cache."""
    api, params = lm
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 200, 20).astype(np.int32)
    for scheduler in ("continuous", "static"):
        eng = E.Engine(api, params, E.ServeConfig(max_seq=24), batch=2,
                       scheduler=scheduler)
        r = eng.generate([E.Request(uid=0, prompt=prompt.copy(), max_new=16)])[0]
        # 1 prefill token + decode writes at positions 20..23
        assert r.done and len(r.out_tokens) == 24 - 20 + 1, (scheduler, r.out_tokens)


def test_slot_utilization_tracked(lm):
    api, params = lm
    eng = E.Engine(api, params, E.ServeConfig(max_seq=64), batch=4)
    eng.generate(_requests(n=6))
    assert eng.stats["slot_steps_total"] == eng.stats["steps"] * 4
    assert 0.0 < eng.slot_utilization() <= 1.0
