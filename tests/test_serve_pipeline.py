"""The device-continuous serving pipeline: AOT-warmed prefill buckets,
packed admission, and the async host loop (serve/pipeline.py + Engine).

Pins the PR's acceptance criteria:
  * packed multi-prompt admission produces bitwise-identical greedy tokens
    and identical page-allocation accounting to one-at-a-time admission
    (dense + paged, single-device + forced-4-host-device mesh);
  * the one-step-deep async loop is bitwise the synchronous loop;
  * after warmup, an on-ladder workload triggers ZERO new jit traces, and
    an off-ladder prompt raises explicitly instead of silently compiling;
  * Engine.stats attributes warmup / device / host time separately and
    latency_stats() reports p50/p99 TTFT and inter-token latency.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api as model_api
from repro.serve import engine as E
from repro.serve import pipeline as pl

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i,
                      prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


# ---------------------------------------------------------------------------
# Ladder + worker units (no model)
# ---------------------------------------------------------------------------

def test_auto_ladder_and_bucketing():
    assert pl.auto_buckets(48) == (8, 16, 32, 48)
    assert pl.auto_buckets(64) == (8, 16, 32, 64)
    lad = pl.PrefillLadder.build(64)
    assert lad.bucket_for(3) == 8
    assert lad.bucket_for(16) == 16
    assert lad.bucket_for(17) == 32
    with pytest.raises(ValueError, match="bucket"):
        lad.bucket_for(65)
    # explicit ladders narrow the compile surface; validation is strict
    lad2 = pl.PrefillLadder.build(64, buckets=(16, 48))
    assert lad2.bucket_for(20) == 48
    with pytest.raises(ValueError, match="bucket"):
        lad2.bucket_for(49)
    with pytest.raises(ValueError, match="multiple"):
        pl.PrefillLadder.build(64, buckets=(12,))
    with pytest.raises(ValueError, match="max_seq"):
        pl.PrefillLadder.build(64, buckets=(128,))
    # admission row counts: powers of two plus the full batch
    assert lad.row_counts(4) == (1, 2, 4)
    assert lad.row_counts(6) == (1, 2, 4, 6)
    assert lad.pad_rows(3, 4) == 4
    assert lad.pad_rows(5, 6) == 6


def test_background_worker_order_and_error_propagation():
    w = pl.BackgroundWorker()
    out = []
    for i in range(200):
        w.submit(functools.partial(out.append, i))
    w.flush()
    assert out == list(range(200))  # strict submission order
    w.submit(lambda: 1 / 0)
    with pytest.raises(ZeroDivisionError):
        w.flush()  # a bookkeeping bug fails the serve thread, not silence
    w.submit(out.clear)
    w.close()
    assert out == []


def test_background_worker_error_skips_queued_ops_and_poisons_submit():
    """A failed transfer op must not let later queued ops run against the
    broken state: everything behind the failure is skipped, and a submit
    racing the un-surfaced error re-raises it instead of enqueueing."""
    w = pl.BackgroundWorker()
    ran = []
    release = pl.threading.Event()
    w.submit(release.wait)              # hold the queue so ordering is ours
    w.submit(lambda: 1 / 0)             # the failing transfer op
    w.submit(functools.partial(ran.append, "after-error"))
    release.set()
    w._q.join()                         # error captured, not yet surfaced
    with pytest.raises(ZeroDivisionError):
        w.submit(functools.partial(ran.append, "poisoned"))
    assert ran == []                    # neither queued-behind nor poisoned ran
    # the poisoned submit SURFACED the error (one error, one raise); the
    # worker is usable again afterwards — pinned recovery semantics
    w.submit(functools.partial(ran.append, "recovered"))
    w.flush()
    assert ran == ["recovered"]
    w.close()


def test_background_worker_error_surfaces_on_close():
    """close() is a surfacing point too: a failure with no intervening
    flush()/submit() must still fail the serve thread at teardown."""
    w = pl.BackgroundWorker()
    w.submit(lambda: [][1])
    with pytest.raises(IndexError):
        w.close()
    # close() already joined the thread; a fresh worker is required
    w2 = pl.BackgroundWorker()
    boom = RuntimeError("transfer failed")
    def fail():
        raise boom
    w2.submit(fail)
    with pytest.raises(RuntimeError) as ei:
        w2.flush()
    assert ei.value is boom             # the op's OWN exception, unwrapped
    w2.close()


# ---------------------------------------------------------------------------
# Zero compilation under traffic (the AOT warmup contract)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def warm(lm):
    """One warmed engine + its post-warmup trace snapshot (max_seq=16 keeps
    the ladder at 2 buckets x 2 row counts)."""
    api, params = lm
    sc = E.ServeConfig(max_seq=16, kv_compress=True, kv_keep=8,
                       codec_backend="reference", aot_warmup=True)
    eng = E.Engine(api, params, sc, batch=2)
    return eng, eng.trace_counts.snapshot()


def test_warmup_compiles_the_whole_ladder(warm):
    eng, snap = warm
    assert eng.stats["warmup_s"] > 0.0
    assert eng.ladder.buckets == (8, 16)
    # every (rows x bucket) admission shape compiled ahead of traffic
    assert snap["prefill"] == len(eng.ladder.buckets) * \
        len(eng.ladder.row_counts(eng.batch))
    assert snap["decode"] == 1 and snap["fix"] == 1 and snap["reset"] == 1


def test_zero_new_traces_for_on_ladder_traffic(warm):
    eng, snap = warm
    rng = np.random.default_rng(1)
    reqs = [E.Request(uid=i, prompt=rng.integers(0, 200, p).astype(np.int32),
                      max_new=3) for i, p in enumerate([5, 9, 14, 16, 3])]
    done = eng.generate(reqs)
    assert all(r.done for r in done)
    assert eng.stats["steps"] > 0
    assert eng.trace_counts.delta(snap) == {}  # nothing compiled under traffic


def test_stats_split_and_latency_metrics(warm):
    eng, _ = warm
    s = eng.stats
    assert s["warmup_s"] > 0 and s["prefill_s"] > 0 and s["decode_s"] > 0
    assert s["host_s"] >= 0.0  # bookkeeping no longer hides inside decode_s
    lat = eng.latency_stats()
    assert set(lat) == {"ttft_p50_s", "ttft_p99_s", "itl_p50_s", "itl_p99_s"}
    assert lat["ttft_p50_s"] > 0 and lat["itl_p50_s"] > 0
    assert lat["ttft_p99_s"] >= lat["ttft_p50_s"]
    assert lat["itl_p99_s"] >= lat["itl_p50_s"]


def test_off_ladder_prompt_raises_instead_of_compiling(lm):
    api, params = lm
    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference", prefill_buckets=(8, 16))
    eng = E.Engine(api, params, sc, batch=2)
    with pytest.raises(ValueError, match="bucket"):
        eng.generate([E.Request(uid=0, prompt=np.zeros(20, np.int32),
                                max_new=2)])


# ---------------------------------------------------------------------------
# Packed admission + async loop: bitwise parity with the serial/sync path
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_packed_admission_parity_dense(lm):
    """A mixed-length workload admitted via packed multi-prompt prefill is
    bitwise the serial one-at-a-time loop (which is the pre-pipeline
    engine), greedy, dense pool."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    packed = E.Engine(api, params, E.ServeConfig(**kw), batch=4)
    serial = E.Engine(api, params,
                      E.ServeConfig(**kw, packed_admission=False,
                                    async_host=False), batch=4)
    a = packed.generate(_requests())
    b = serial.generate(_requests())
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    assert packed.stats["tokens_out"] == serial.stats["tokens_out"]


@pytest.mark.slow
def test_packed_admission_parity_paged_page_accounting(lm):
    """Paged pool: packed admission must issue the SAME page ids to the
    same slots in the same order as serial admission (the allocator is
    deterministic), produce bitwise tokens, and drain the pool fully.

    Page-id order is compared at matched pipeline depth: the one-step-deep
    async loop admits a freed slot one decode step later than the sync
    loop (the speculative step is already in flight), which can reorder
    page RECYCLING without affecting tokens — so the packed-vs-serial
    comparison holds async fixed, and the sync engine pins tokens only."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference", pool_pages=24)

    def run(**over):
        eng = E.Engine(api, params, E.ServeConfig(**kw, **over), batch=4)
        issued = []
        inner = eng._admit

        def spy(r, c, i):
            issued.append((r.uid, i, tuple(eng._slot_pages[i])))
            return inner(r, c, i)

        eng._admit = spy
        done = eng.generate(_requests())
        assert sorted(eng._free_pages) == list(range(24))  # fully drained
        return ([r.out_tokens for r in done], issued,
                eng.stats["peak_pages_in_use"])

    toks_sync, _, _ = run(packed_admission=False, async_host=False)
    toks_serial, issued_serial, peak_serial = run(packed_admission=False)
    toks_packed, issued_packed, peak_packed = run()
    assert toks_packed == toks_serial == toks_sync  # bitwise, all modes
    assert issued_packed == issued_serial  # same pages, same slots, same order
    assert peak_packed == peak_serial


@pytest.mark.slow
def test_async_pipeline_matches_sync_loop(lm):
    """One-step-deep dispatch (read step t while t+1 runs) changes wall
    time only: per-request greedy tokens are bitwise the synchronous
    loop's, through retirement and slot reuse."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    a = E.Engine(api, params, E.ServeConfig(**kw), batch=3) \
        .generate(_requests())
    b = E.Engine(api, params, E.ServeConfig(**kw, async_host=False),
                 batch=3).generate(_requests())
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]


@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")
@pytest.mark.parametrize("pool", [None, 24], ids=["dense", "paged"])
def test_packed_admission_parity_on_mesh(lm, pool):
    """Packed admission + async pipeline on a 4x1 mesh == the serial sync
    single-device engine, dense and paged."""
    from repro.parallel import mesh as mesh_lib

    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference", pool_pages=pool)
    base = E.Engine(api, params,
                    E.ServeConfig(**kw, packed_admission=False,
                                  async_host=False), batch=4) \
        .generate(_requests())
    eng = E.Engine(api, params,
                   E.ServeConfig(**kw,
                                 mesh=mesh_lib.make_serve_mesh("4x1")),
                   batch=4)
    got = eng.generate(_requests())
    assert [r.out_tokens for r in got] == [r.out_tokens for r in base]
    if pool:
        assert sorted(eng._free_pages) == list(range(pool))
