"""Mesh-native serving: the engine on a (data x model) host mesh must be a
pure placement change — greedy tokens bitwise identical to the single-device
engine for compressed (uniform + pyramid plan) and raw caches, including
slot retirement/re-admission — and the decode step must compile shard-local
(no full-cache all-gather in its HLO).

Runs under XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
multidevice job sets it); skipped when fewer than 4 devices exist, so the
plain tier-1 invocation is unaffected.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.parallel import sharding as sh
from repro.serve import engine as E

pytestmark = pytest.mark.slow  # mesh parity: tier1-mesh job only

if len(jax.devices()) < 4:
    pytest.skip(
        "needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
        allow_module_level=True)

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]
PYRAMID = "0-1:keep=8,2-:keep=4"  # 2 segments over the 4 reduced layers
MESHES = ("4x1", "2x2")


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i, prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


def _serve(api, params, sc, batch=4, n=8):
    eng = E.Engine(api, params, sc, batch=batch)
    done = eng.generate(_requests(n))
    assert all(r.done for r in done)
    return [r.out_tokens for r in done], eng


# ---------------------------------------------------------------------------
# Bitwise greedy parity: sharded pool == single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_spec", MESHES)
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_compressed_parity_on_mesh(lm, mesh_spec, plan):
    """8 requests through 4 slots (retirement + re-admission) over the
    compressed pool: per-request greedy outputs must match the single-device
    engine token for token — the mesh is a placement change only."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference")
    base, _ = _serve(api, params, E.ServeConfig(**kw))
    got, eng = _serve(api, params,
                      E.ServeConfig(**kw, mesh=mesh_lib.make_serve_mesh(mesh_spec)))
    assert eng.scheduler == "continuous"
    assert eng.stats["requests"] == 8  # 8 requests over 4 slots => slot reuse
    assert got == base


@pytest.mark.parametrize("mesh_spec", MESHES)
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_paged_pool_parity_on_mesh(lm, mesh_spec, plan):
    """The PAGED pool on a mesh (pages + block tables on `data`, heads on
    `model`) must reproduce the single-device dense engine bit for bit,
    through retirement/re-admission and host-side page reuse."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference")
    base, _ = _serve(api, params, E.ServeConfig(**kw))
    got, eng = _serve(api, params,
                      E.ServeConfig(**kw, pool_pages=24,
                                    mesh=mesh_lib.make_serve_mesh(mesh_spec)))
    assert eng.paged and eng.scheduler == "continuous"
    assert got == base
    assert sorted(eng._free_pages) == list(range(24))  # pool fully drained


@pytest.mark.parametrize("mesh_spec", MESHES)
def test_raw_parity_on_mesh(lm, mesh_spec):
    api, params = lm
    base, _ = _serve(api, params, E.ServeConfig(max_seq=64))
    got, _ = _serve(api, params,
                    E.ServeConfig(max_seq=64,
                                  mesh=mesh_lib.make_serve_mesh(mesh_spec)))
    assert got == base


def test_nondivisible_heads_parity_on_mesh(lm):
    """model=4 with n_kv_heads=2: cache_specs falls back to sharding the
    S/8 block axis on `model`, and the in-step hints must follow the same
    rule (heads-else-blocks) — parity pins the layout against regressions."""
    api, params = lm
    assert api.cfg.n_kv_heads % 4 != 0  # the case under test
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    base, _ = _serve(api, params, E.ServeConfig(**kw))
    got, _ = _serve(api, params,
                    E.ServeConfig(**kw, mesh=mesh_lib.make_serve_mesh("1x4")))
    assert got == base


def test_mla_parity_on_mesh():
    """MLA latent cache (c_kv/k_rope leaves) shards on the same rules."""
    api = model_api.build_reduced("deepseek_v2_236b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    base, _ = _serve(api, params, E.ServeConfig(max_seq=64), n=4)
    got, _ = _serve(api, params,
                    E.ServeConfig(max_seq=64,
                                  mesh=mesh_lib.make_serve_mesh("4x1")), n=4)
    assert got == base


def test_eos_retirement_parity_on_mesh(lm):
    """Mid-stream EOS retires slots and re-admits queued requests: the
    sharded engine must retire/reuse identically (same truncations)."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    probe, _ = _serve(api, params, E.ServeConfig(**kw), batch=2)
    eos = next(t for toks in probe for t in toks[1:-1])
    base, _ = _serve(api, params, E.ServeConfig(**kw, eos_id=eos), batch=2)
    got, eng = _serve(api, params,
                      E.ServeConfig(**kw, eos_id=eos,
                                    mesh=mesh_lib.make_serve_mesh("2x2")),
                      batch=2)
    assert got == base
    assert eng.stats["requests"] == 8


def test_static_scheduler_parity_on_mesh(lm):
    """Wave-at-a-time baseline under a mesh (scalar pos, full-batch prefill)."""
    api, params = lm
    def run(sc):
        eng = E.Engine(api, params, sc, batch=4, scheduler="static")
        return [r.out_tokens for r in eng.generate(_requests())]
    base = run(E.ServeConfig(max_seq=64))
    got = run(E.ServeConfig(max_seq=64, mesh=mesh_lib.make_serve_mesh("4x1")))
    assert got == base


# ---------------------------------------------------------------------------
# Compiled placement: explicit shardings, shard-local decode
# ---------------------------------------------------------------------------

def test_decode_hlo_has_no_full_cache_all_gather(lm):
    """Acceptance criterion: the jitted decode step runs under explicit
    NamedShardings and its optimized HLO never gathers the cache — every
    all-gather (flush-block updates, scatter indices) must be per-token
    sized, independent of max_seq."""
    api, params = lm
    mesh = mesh_lib.make_serve_mesh("4x1")
    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference", mesh=mesh)
    eng = E.Engine(api, params, sc, batch=4)
    with mesh_lib.use_mesh(mesh):
        cache = eng._cache_init(4)
        args = (eng.params, jnp.zeros((4,), jnp.int32), cache,
                jnp.zeros((4,), jnp.int32))
        txt = eng._decode.lower(*args).compile().as_text()
    # one segment: packed_k (L, B, ns, Hkv, hd/8, k, k) int8 — the smallest
    # full-cache plane anything could gather
    seg = cache.segments[0]
    plane_bytes = int(np.prod(seg.packed_k.shape))
    gathered = []
    for m in re.finditer(r"all-gather[^=]*= (\w+)\[([\d,]*)\]", txt):
        dtype, dims = m.group(1), m.group(2)
        n = int(np.prod([int(d) for d in dims.split(",")])) if dims else 1
        itemsize = {"f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
                    "s8": 1, "u8": 1, "pred": 1}.get(dtype, 4)
        gathered.append((n * itemsize, m.group(0)))
    for nbytes, line in gathered:
        assert nbytes < plane_bytes / 2, (nbytes, plane_bytes, line)


def test_decode_io_shardings_are_explicit(lm):
    """Decode in/out shardings: cache batch slots on data, (B,) vectors on
    data — verified on the compiled executable, not just the spec tree."""
    api, params = lm
    mesh = mesh_lib.make_serve_mesh("4x1")
    sc = E.ServeConfig(max_seq=64, kv_compress=True, kv_keep=8,
                       codec_backend="reference", mesh=mesh)
    eng = E.Engine(api, params, sc, batch=4)
    with mesh_lib.use_mesh(mesh):
        cache = eng._cache_init(4)
        tok, pos1, cache2 = eng._decode(eng.params, jnp.zeros((4,), jnp.int32),
                                        cache, jnp.zeros((4,), jnp.int32))
    def batch_axis(arr):
        return arr.sharding.spec[1]
    for segment in cache2.segments:
        for name in ("packed_k", "scale_k", "packed_v", "scale_v",
                     "tail_k", "tail_v"):
            spec_entry = batch_axis(getattr(segment, name))
            assert spec_entry in ("data", ("data",)), (name, spec_entry)
    # the fused step's (B,) sampled-token / pos outputs — the only tensors
    # the async loop reads back — ride the data axes like the slots
    for vec in (tok, pos1):
        assert vec.sharding.spec[0] in ("data", ("data",)), vec.sharding.spec


def test_cache_specs_cover_kv_segments(lm):
    """cache_specs dispatches by field name straight off the KVSegment
    pytree (uniform and pyramid plans), and kv_pool_specs builds the same
    tree from (cfg, plan, mesh) alone."""
    from repro.core import kv_cache as KV

    api, params = lm
    cfg = api.cfg
    mesh = mesh_lib.make_serve_mesh("2x2")
    for plan in (8, PYRAMID):
        shapes = jax.eval_shape(
            lambda: KV.init_compressed_cache(cfg, 4, 64, plan=plan))
        specs = sh.cache_specs(shapes, cfg, mesh)
        pool_specs = sh.kv_pool_specs(cfg, plan, mesh, batch=4, max_seq=64)
        assert jax.tree.structure(specs, is_leaf=lambda s: isinstance(s, P)) \
            == jax.tree.structure(pool_specs, is_leaf=lambda s: isinstance(s, P))
        for seg_spec in specs.segments:
            # slots on data; kv heads (2) divide model (2) => head-sharded
            assert seg_spec.packed_k[1] in ("data", ("data",))
            assert seg_spec.packed_k[3] == "model"
            assert seg_spec.tail_k[3] == "model"
        # per-device bytes: data x model both divide their axes => 4x split
        total = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(shapes))
        per_dev = sh.per_device_bytes(shapes, specs, mesh)
        assert per_dev == pytest.approx(total / 4)
