"""Sharding-rule unit tests + HLO roofline analyzer tests (no big compiles)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh
from repro.roofline import analysis as RA
from repro.roofline import hlo as H


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_spec_rules(mesh):
    params = {
        "embed": jnp.zeros((256, 64)),
        "layers": {
            "attn": {"wq": {"w": jnp.zeros((4, 64, 64))},
                     "wo": {"w": jnp.zeros((4, 64, 64))}},
            "mlp": {"wu": {"w": jnp.zeros((4, 64, 128))},
                    "wd": {"w": jnp.zeros((4, 128, 64))}},
            "ln1": {"g": jnp.zeros((4, 64))},
        },
        "moe_layers": {"moe": {"wg": jnp.zeros((4, 8, 64, 32)),
                               "router": {"w": jnp.zeros((4, 64, 8))}}},
    }
    def norm(spec):
        # newer jax normalizes 1-tuples to bare names; compare canonically
        return tuple(p[0] if isinstance(p, tuple) and len(p) == 1 else p
                     for p in spec)

    specs = sh.param_specs(params, mesh, fsdp=True)
    assert norm(specs["embed"]) == norm(P("model", ("data",)))
    assert norm(specs["layers"]["attn"]["wq"]["w"]) == norm(P(None, ("data",), "model"))
    assert norm(specs["layers"]["attn"]["wo"]["w"]) == norm(P(None, "model", ("data",)))
    assert norm(specs["layers"]["mlp"]["wd"]["w"]) == norm(P(None, "model", ("data",)))
    assert norm(specs["layers"]["ln1"]["g"]) == norm(P(None, None))
    assert norm(specs["moe_layers"]["moe"]["wg"]) == norm(P(None, "model", ("data",), None))
    assert norm(specs["moe_layers"]["moe"]["router"]["w"]) == norm(P(None, ("data",), None))


def test_fit_spec_divisibility():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model")) \
        if len(jax.devices()) >= 8 else None
    if mesh is None:
        pytest.skip("needs 8 devices")
    # batch 1 cannot shard over ("pod","data")
    assert sh.fit_spec(P(("pod", "data")), (1,), mesh) == P(None)
    # batch 2 shards over pod only
    assert sh.fit_spec(P(("pod", "data")), (2,), mesh) == P("pod")
    # odd vocab cannot shard over model
    assert sh.fit_spec(P("model", None), (51865, 512), mesh) == P(None, None)
    assert sh.fit_spec(P("model", None), (512, 64), mesh) == P("model", None)


def test_cache_specs_dispatch(mesh):
    from repro.configs.base import get_config

    cfg = get_config("yi_6b")
    cache = {
        "k": jax.ShapeDtypeStruct((32, 8, 64, 4, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((32, 8, 64, 4, 128), jnp.bfloat16),
    }
    specs = sh.cache_specs(cache, cfg, mesh)
    assert len(specs["k"]) == 5


def test_cache_specs_dispatch_on_kv_segments(mesh):
    """The KVSegment pytree registers key paths, so the same name-dispatch
    rules cover the serve engine's CompressedKVCache directly — including
    per-policy (pyramid) plans with one spec set per segment."""
    from repro.configs.base import get_config
    from repro.core import kv_cache as KV

    cfg = get_config("yi_6b").reduced()
    shapes = jax.eval_shape(
        lambda: KV.init_compressed_cache(cfg, 4, 64, plan="0-1:keep=8,2-:keep=4"))
    specs = sh.cache_specs(shapes, cfg, mesh)
    assert len(specs.segments) == 2
    for seg_shapes, seg_spec in zip(shapes.segments, specs.segments):
        assert len(seg_spec.packed_k) == seg_shapes.packed_k.ndim == 7
        assert len(seg_spec.tail_k) == seg_shapes.tail_k.ndim == 5
    # kv_pool_specs builds the identical tree from (cfg, plan, mesh) alone
    pool = sh.kv_pool_specs(cfg, "0-1:keep=8,2-:keep=4", mesh, batch=4,
                            max_seq=64)
    assert specs == pool


def test_per_device_bytes_counts_shard_factors(mesh):
    shapes = {"a": jax.ShapeDtypeStruct((8, 16), jnp.float32),
              "b": jax.ShapeDtypeStruct((4,), jnp.int8)}
    specs = {"a": P(("data",), "model"), "b": P(None)}
    # 1x1 module mesh: factors are 1 -> exact byte total
    assert sh.per_device_bytes(shapes, specs, mesh) == 8 * 16 * 4 + 4


def test_make_serve_mesh_spec_parsing():
    from repro.parallel import mesh as mesh_lib

    assert mesh_lib.parse_mesh_spec("4x1") == (4, 1)
    assert mesh_lib.parse_mesh_spec("2X2") == (2, 2)
    assert mesh_lib.make_serve_mesh(None) is None
    assert mesh_lib.make_serve_mesh("") is None
    with pytest.raises(ValueError):
        mesh_lib.parse_mesh_spec("4")
    with pytest.raises(ValueError):
        mesh_lib.parse_mesh_spec("0x2")
    n = len(jax.devices())
    with pytest.raises(ValueError):
        mesh_lib.make_serve_mesh(f"{n + 1}x1")
    m = mesh_lib.make_serve_mesh(f"{n}x1")
    assert tuple(m.axis_names) == ("data", "model")
    assert m.shape["data"] == n


def test_launch_mesh_is_a_reexport():
    from repro.launch import mesh as launch_mesh
    from repro.parallel import mesh as parallel_mesh

    assert launch_mesh.make_production_mesh is parallel_mesh.make_production_mesh


def test_hlo_type_bytes():
    assert H._type_bytes("f32[4,8]") == 128
    assert H._type_bytes("bf16[10]{0}") == 20
    assert H._type_bytes("(f32[2], s8[3])") == 11
    assert H._type_bytes("pred[]") == 1  # scalars: dims empty -> 1 elem


def test_hlo_dot_flops():
    types = {"%a": "f32[16,32]", "%b": "f32[32,8]"}
    line = "%dot = f32[16,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}"
    assert H._dot_flops(line, "f32[16,8]", types) == 2 * 16 * 8 * 32


def test_hlo_while_trip_multiplication():
    text = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    st = H.analyze(text)
    assert st.flops == 5 * 2 * 8 * 8 * 8


def test_collective_wire_model():
    text = """
HloModule t

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%a), replica_groups={}, to_apply=%add
  ROOT %ag = f32[64,64]{1,0} all-gather(%ar), dimensions={0}
}
"""
    st = H.analyze(text)
    assert st.coll["all-reduce"]["wire_bytes"] == 2 * 64 * 64 * 4
    # ag result==operand sizes here -> wire 0 by (res - ops); fine as a parse test
    assert st.coll["all-gather"]["count"] == 1


def test_roofline_terms_and_dominance():
    r = RA.Roofline(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        hlo_flops=197e12, hlo_bytes=819e9 * 2, wire_bytes=50e9 * 0.5,
        model_flops_global=197e12 * 256 * 0.5,
    )
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.roofline_fraction == pytest.approx(2.0 / 3.5)
    assert r.useful_flop_ratio == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro.configs.base import get_config

    cfg = get_config("yi_6b")
    tr = RA.model_flops(cfg, "train_4k")
    pf = RA.model_flops(cfg, "prefill_32k")
    dc = RA.model_flops(cfg, "decode_32k")
    assert tr > pf > dc
    assert tr == pytest.approx(6 * cfg.param_counts()["active"] * 256 * 4096)
