"""Tiered page pool: host offload of cold compressed pages + copy-on-write
prefix sharing.

The device pool (PR 5-7) is the paper's on-chip feature-map buffer; the
host tier is its off-chip DRAM, affordable because pages move compressed
(int8 DCT blocks + scales). These tests pin the two correctness contracts:

  * TIERING IS PLACEMENT ONLY — greedy tokens with forced eviction (device
    pool barely one request's horizon) are bitwise the untiered pool's, on
    uniform + pyramid plans, single-device and 4x1 mesh, with the page
    ledger (`check_page_invariants`) balancing after every admission flush
    and retirement (`paranoid_pool_checks`).
  * SHARING IS STORAGE ONLY — identical prompt prefixes map the same
    physical pages, admission reserves just the unshared suffix, and a
    forced hash collision costs a demotion (fresh pages), never aliased
    output: the device-side bitwise verification, not the hash, is the
    safety boundary.

Fast tests cover the host-side allocator pieces (TierManager round trip,
PrefixIndex, config resolution) and a small-model engine parity; the
yi_6b engine parities and the mesh leg are `slow` (tier1-mesh job).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kv_cache as KV
from repro.models import api as model_api
from repro.parallel import mesh as mesh_lib
from repro.serve import engine as E
from repro.serve import tiering

PLENS = [5, 9, 12, 16, 3, 21, 8, 14]
MAX_NEWS = [3, 7, 5, 9, 4, 6, 8, 5]
PYRAMID = "0-1:keep=8,2-:keep=4"


@pytest.fixture(scope="module")
def lm_small():
    api = model_api.build_reduced("qwen2_0_5b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


@pytest.fixture(scope="module")
def lm():
    api = model_api.build_reduced("yi_6b")
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    return api, params


def _requests(n=8, seed=42):
    rng = np.random.default_rng(seed)
    return [E.Request(uid=i,
                      prompt=rng.integers(0, 200, PLENS[i]).astype(np.int32),
                      max_new=MAX_NEWS[i]) for i in range(n)]


def _shared_prefix_requests(n=8, seed=7, pre_tokens=16, suf_tokens=4,
                            max_new=12):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, 200, pre_tokens).astype(np.int32)
    return [E.Request(uid=i, prompt=np.concatenate(
        [pre, rng.integers(0, 200, suf_tokens).astype(np.int32)]),
        max_new=max_new) for i in range(n)]


def _parity(base, got):
    for a, b in zip(base, got):
        assert a.out_tokens == b.out_tokens, \
            (a.uid, a.out_tokens, b.out_tokens)


# ---------------------------------------------------------------------------
# Prefix hash: hypothesis-free mirror of the property tests
# ---------------------------------------------------------------------------

def test_prefix_block_keys_properties():
    """Chained content keys: full blocks only, pure function of the tokens,
    padding/extension invariant, divergent from the first differing block.
    (The hypothesis version lives in test_prefix_hash_property.py.)"""
    rng = np.random.default_rng(0)
    for plen in (0, 3, 8, 11, 16, 29, 64):
        arr = rng.integers(0, 2**31 - 1, plen).astype(np.int32)
        keys = tiering.prefix_block_keys(arr)
        assert len(keys) == plen // 8
        assert keys == tiering.prefix_block_keys(arr)  # deterministic
        # batch-padding / extension invariance: appending anything never
        # rewrites a completed block's key
        padded = np.concatenate([arr, rng.integers(0, 99, 13).astype(np.int32)])
        assert tiering.prefix_block_keys(padded)[:len(keys)] == keys
        if plen >= 8:
            for flip in (0, plen // 2, 8 * (plen // 8) - 1):
                mut = arr.copy()
                mut[flip] ^= 1
                km = tiering.prefix_block_keys(mut)
                blk = flip // 8
                assert km[:blk] == keys[:blk]
                assert all(a != b for a, b in zip(km[blk:], keys[blk:]))


def test_prefix_index_bimap_and_leading_run():
    idx = tiering.PrefixIndex()
    ka, kb, kc = b"a", b"b", b"c"
    idx.register(ka, 3)
    idx.register(kb, 5)
    idx.register(ka, 9)  # first writer wins
    assert idx.lookup_run([ka, kb, kc]) == [3, 5]
    assert idx.lookup_run([kc, ka]) == []      # run must be LEADING
    idx.drop_page(3)                           # freed/spilled page leaves
    assert idx.lookup_run([ka, kb]) == []
    assert len(idx) == 1
    idx.register(ka, 7)                        # key is re-registerable
    assert idx.lookup_run([ka, kb]) == [7, 5]


# ---------------------------------------------------------------------------
# TierManager: host store round trip is bitwise
# ---------------------------------------------------------------------------

def test_tier_manager_roundtrip_bitwise(lm_small):
    """gather -> stage_out -> read_back -> paged_write_slot returns page
    content (packed int8, f32 scales, bf16 tails) bit-for-bit."""
    api, _ = lm_small
    cfg = api.cfg
    mk = lambda: KV.init_paged_cache(cfg, 2, 32, 6)
    rng = np.random.default_rng(3)
    cache = jax.tree.map(
        lambda l: jnp.asarray(rng.standard_normal(l.shape) * 8).astype(l.dtype),
        mk())
    ids = jnp.asarray(np.array([0, 1, 2], np.int32))
    upd = KV.paged_gather_slot(cache, jnp.int32(0), ids)

    tier = tiering.TierManager(jax.eval_shape(mk), host_pages=5)
    assert tier.free_pages == 5 and tier.in_use == 0
    hids = tier.alloc(3)
    assert tier.in_use == 3
    with pytest.raises(RuntimeError, match="host page pool exhausted"):
        tier.alloc(3)
    tier.stage_out(hids, jax.tree.map(np.asarray, upd))

    back = tier.read_back(list(enumerate(hids)), nbkt=3)
    back = [dict(seg, **{k: np.asarray(u[k]) for k in tiering.TAIL_KEYS})
            for seg, u in zip(back, upd)]
    row = np.zeros(32 // 8, np.int32)
    row[:3] = [3, 4, 5]
    restored = KV.paged_write_slot(mk(), back, jnp.int32(1),
                                   jnp.asarray(row[:3]), jnp.asarray(row))
    upd2 = KV.paged_gather_slot(restored, jnp.int32(1),
                                jnp.asarray(np.array([3, 4, 5], np.int32)))
    for seg_a, seg_b in zip(upd, upd2):
        for key in tiering.PAGE_KEYS:
            np.testing.assert_array_equal(np.asarray(seg_a[key]),
                                          np.asarray(seg_b[key]), err_msg=key)
    tier.release(hids)
    assert tier.free_pages == 5


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def test_resolved_host_pages_and_validation(lm_small):
    api, params = lm_small
    cfg = api.cfg
    page_b = E.ServeConfig(kv_compress=True, kv_keep=8) \
        .resolved_plan().page_bytes(cfg)
    sc = E.ServeConfig(kv_compress=True, kv_keep=8, pool_pages=4,
                       host_pool_mb=(10 * page_b) / 1e6)
    assert sc.tiered and sc.resolved_host_pages(cfg) == 10
    assert E.ServeConfig(kv_compress=True, kv_keep=8, pool_pages=4,
                         host_pool_pages=7).resolved_host_pages(cfg) == 7
    with pytest.raises(ValueError, match="holds no page"):
        E.ServeConfig(kv_compress=True, kv_keep=8, pool_pages=4,
                      host_pool_mb=1e-9).resolved_host_pages(cfg)
    # tiering/sharing ride the paged allocator; a dense pool has no pages
    for kw in ({"host_pool_pages": 8}, {"prefix_sharing": True}):
        with pytest.raises(ValueError, match="paged KV pool"):
            E.Engine(api, params,
                     E.ServeConfig(max_seq=32, kv_compress=True, kv_keep=8,
                                   **kw), batch=2)


# ---------------------------------------------------------------------------
# Engine parity, small model (fast) — forced offload + sharing together
# ---------------------------------------------------------------------------

def test_tiered_and_shared_parity_small(lm_small):
    """qwen2-reduced: device pool of 4 pages + host tier + prefix sharing
    serves the mixed workload bitwise-identically to a big untiered pool,
    with the ledger checked after every admission/retirement."""
    api, params = lm_small
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference")
    base = E.Engine(api, params, E.ServeConfig(**kw, pool_pages=32),
                    batch=4).generate(_requests())
    eng = E.Engine(api, params,
                   E.ServeConfig(**kw, pool_pages=4, host_pool_pages=32,
                                 prefix_sharing=True), batch=4)
    eng.paranoid_pool_checks = True
    got = eng.generate(_requests())
    _parity(base, got)
    assert eng.stats["slots_parked"] == eng.stats["slots_resumed"]
    assert eng.stats["pages_spilled"] == eng.stats["pages_restored"]
    assert eng.stats["slots_parked"] > 0   # the tiny pool forced offload
    st = eng.kv_pool_stats()               # runs check_page_invariants()
    assert st["pages_host_in_use"] == 0    # everything streamed back
    assert sorted(eng._free_pages) == list(range(4))


# ---------------------------------------------------------------------------
# Engine parity, yi_6b (slow): uniform + pyramid, offload / sharing legs
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_host_offload_bitwise_matches_untiered(lm, plan):
    """Acceptance: eviction forced by a 4-page device pool (vs 32 untiered)
    changes NOTHING about the tokens — spill/restore is placement only."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference")
    base = E.Engine(api, params, E.ServeConfig(**kw, pool_pages=32),
                    batch=4).generate(_requests())
    eng = E.Engine(api, params,
                   E.ServeConfig(**kw, pool_pages=4, host_pool_pages=32,
                                 aot_warmup=True), batch=4)
    eng.paranoid_pool_checks = True
    snap = eng.trace_counts.snapshot()
    got = eng.generate(_requests())
    _parity(base, got)
    assert eng.trace_counts.delta(snap) == {}  # fault path rode the warmup
    assert eng.stats["slots_parked"] > 0
    assert eng.stats["pages_spilled"] > 0
    assert eng.stats["pages_spilled"] == eng.stats["pages_restored"]
    eng.kv_pool_stats()
    assert sorted(eng._free_pages) == list(range(4))  # full drain


@pytest.mark.slow
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_prefix_sharing_bitwise_and_page_counts(lm, plan):
    """Acceptance: sharing on vs off is bitwise; N slots sharing a 2-block
    prefix peak at exactly 1x prefix + Nx suffix-horizon physical pages."""
    api, params = lm
    n = 4
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference", pool_pages=2 + n * 1,
              aot_warmup=True)
    base = E.Engine(api, params, E.ServeConfig(**kw), batch=n) \
        .generate(_shared_prefix_requests(n))
    eng = E.Engine(api, params, E.ServeConfig(**kw, prefix_sharing=True),
                   batch=n)
    eng.paranoid_pool_checks = True
    snap = eng.trace_counts.snapshot()
    got = eng.generate(_shared_prefix_requests(n))
    _parity(base, got)
    assert eng.trace_counts.delta(snap) == {}
    st = eng.kv_pool_stats()
    # (16+4+12-1)//8 = 3 pages/request: 2 shared + 1 own suffix. Stored
    # once: peak = 2 + n, and every slot ran concurrently at a budget the
    # unshared engine cannot even fit two full reservations into.
    assert st["peak_pages_in_use"] == 2 + n
    assert st["prefix_shared_blocks"] == 2 * (n - 1)
    assert st["prefix_demotions"] == 0
    assert eng.stats["peak_live_slots"] == n
    assert sorted(eng._free_pages) == list(range(2 + n))


@pytest.mark.slow
def test_hash_collision_demotes_instead_of_aliasing(lm):
    """Force total hash collisions (constant key_fn): every admission sees
    bogus share candidates, the device-side bitwise verification rejects
    them, and outputs stay exactly the unshared engine's — the hash is an
    optimization, the verification is the correctness boundary."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, kv_keep=8,
              codec_backend="reference", pool_pages=32)
    base = E.Engine(api, params, E.ServeConfig(**kw), batch=4) \
        .generate(_requests())
    eng = E.Engine(api, params, E.ServeConfig(**kw, prefix_sharing=True),
                   batch=4)
    eng.paranoid_pool_checks = True
    eng._prefix.key_fn = \
        lambda prompt: [b"collide"] * (len(prompt) // KV.BLOCK)
    got = eng.generate(_requests())
    _parity(base, got)
    assert eng.stats["prefix_demotions"] > 0   # collisions were caught
    eng.kv_pool_stats()
    assert sorted(eng._free_pages) == list(range(32))


@pytest.mark.slow
@pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")
@pytest.mark.parametrize("plan", [8, PYRAMID], ids=["uniform", "pyramid"])
def test_tiered_and_shared_parity_on_4x1_mesh(lm, plan):
    """Acceptance: the 4x1 mesh engine with host offload + prefix sharing
    (host pages OUTSIDE the mesh, restores re-placed with the pool's
    sharding) is bitwise the single-device untiered engine."""
    api, params = lm
    kw = dict(max_seq=64, kv_compress=True, plan=plan,
              codec_backend="reference")
    base = E.Engine(api, params, E.ServeConfig(**kw, pool_pages=32),
                    batch=4).generate(_requests())
    eng = E.Engine(api, params,
                   E.ServeConfig(**kw, pool_pages=4, host_pool_pages=32,
                                 prefix_sharing=True, aot_warmup=True,
                                 mesh=mesh_lib.make_serve_mesh("4x1")),
                   batch=4)
    eng.paranoid_pool_checks = True
    snap = eng.trace_counts.snapshot()
    got = eng.generate(_requests())
    _parity(base, got)
    assert eng.trace_counts.delta(snap) == {}
    assert eng.stats["slots_parked"] > 0
    eng.kv_pool_stats()
    assert sorted(eng._free_pages) == list(range(4))
