"""Training-stack integration tests: loss decreases, remat modes agree,
optimizer behaves, checkpoint resume is exact."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import TokenStream
from repro.models import api as model_api
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train import step as train_step


@pytest.fixture(scope="module")
def setup():
    api = model_api.build_reduced("qwen2_0_5b")
    ts = TokenStream(vocab_size=api.cfg.vocab_size, seq_len=64, global_batch=8)
    return api, ts


def _run(api, ts, tc, steps=20):
    state = train_step.init_train_state(api, tc)
    step = jax.jit(train_step.make_train_step(api, jax.make_mesh((1,), ("data",)), tc),
                   donate_argnums=(0,))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in ts.batch(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases(setup):
    api, ts = setup
    tc = train_step.TrainConfig(
        microbatches=2, remat="full",
        optimizer=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=50),
    )
    losses, _ = _run(api, ts, tc, steps=25)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def _tree_cosine(a, b):
    num = sum(float(jnp.sum(x * y)) for x, y in
              zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    na = np.sqrt(sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(a)))
    nb = np.sqrt(sum(float(jnp.sum(y * y)) for y in jax.tree.leaves(b)))
    return num / (na * nb)


def test_remat_modes_agree_step1(setup):
    """none == full exactly; compressed-remat gradient alignment is MONOTONE
    in keep and exact-ish at keep=8 (int8 quantization only).

    Note: at RANDOM INIT the residual stream is spectrally white — the
    worst case for DCT truncation — so absolute cosine at small keep is
    pessimistic vs. trained activations (convergence parity is covered by
    test_loss_decreases-style runs with remat='compressed')."""
    api, ts = setup
    batch = {k: jnp.asarray(v) for k, v in ts.batch(0).items()}
    params = api.init(jax.random.PRNGKey(0), dtype=jnp.float32)

    def g(remat, keep=8):
        return jax.grad(
            lambda p: api.loss(p, batch, remat=remat, compress_keep=keep)[0]
        )(params)

    g_none, g_full = g("none"), g("full")
    # "full" remat routes through the bf16-wire gradient boundary (layers.py
    # _matmul_bf16_wgrad + the remat wrapper) — agreement is to bf16 precision
    cos_full = _tree_cosine(g_none, g_full)
    assert cos_full > 0.999, cos_full
    cos8 = _tree_cosine(g_none, g("compressed", keep=8))
    cos4 = _tree_cosine(g_none, g("compressed", keep=4))
    assert cos8 > 0.99, cos8          # quantization-only floor
    assert cos8 >= cos4 - 0.02        # monotone in keep
    assert cos4 > 0.3                 # still descent-aligned at init


def test_compressed_remat_trains(setup):
    """ActCompress end-to-end: training converges ~like full remat."""
    api, ts = setup
    out = {}
    for remat in ("full", "compressed"):
        tc = train_step.TrainConfig(
            microbatches=1, remat=remat, compress_keep=6,
            optimizer=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=50),
        )
        losses, _ = _run(api, ts, tc, steps=25)
        out[remat] = np.mean(losses[-5:])
    assert out["compressed"] < out["full"] + 0.35, out


def test_microbatch_equivalence(setup):
    """1 vs 4 microbatches give identical grads (up to f32 reassociation)."""
    api, ts = setup
    batch = {k: jnp.asarray(v) for k, v in ts.batch(0).items()}
    mesh = jax.make_mesh((1,), ("data",))
    outs = []
    for n in (1, 4):
        tc = train_step.TrainConfig(microbatches=n, remat="none")
        state = train_step.init_train_state(api, tc)
        step = jax.jit(train_step.make_train_step(api, mesh, tc))
        _, m = step(state, batch)
        outs.append(float(m["loss"]))
    assert abs(outs[0] - outs[1]) < 1e-2


def test_grad_clip_and_schedule():
    params = {"w": jnp.ones((8, 8))}
    grads = {"w": jnp.full((8, 8), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(800.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-5)
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(5))) == pytest.approx(5e-4)
    assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_resume_exact(tmp_path, setup):
    """Stop at step 6, restore, continue -> bitwise-identical to uninterrupted."""
    from repro.ckpt import store

    api, ts = setup
    tc = train_step.TrainConfig(
        microbatches=1, remat="full",
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30),
    )
    mesh = jax.make_mesh((1,), ("data",))
    step = jax.jit(train_step.make_train_step(api, mesh, tc))

    def batch(i):
        return {k: jnp.asarray(v) for k, v in ts.batch(i).items()}

    # uninterrupted 10 steps
    state_a = train_step.init_train_state(api, tc)
    for i in range(10):
        state_a, _ = step(state_a, batch(i))

    # interrupted at 6 + resume
    state_b = train_step.init_train_state(api, tc)
    for i in range(6):
        state_b, _ = step(state_b, batch(i))
    root = str(tmp_path / "ck")
    store.save(root, 6, state_b)
    restored, at = store.restore(root, jax.eval_shape(lambda: train_step.init_train_state(api, tc)))
    assert at == 6
    for i in range(6, 10):
        restored, _ = step(restored, batch(i))

    for a, b in zip(jax.tree.leaves(state_a["params"]), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
